"""Level scheduler + jit-compiled change propagation for traced SP-dags.

``CompiledGraph`` takes a ``GraphBuilder`` trace and produces:

  * ``init(**inputs) -> state`` — the initial run (jitted): forward every
    node, store every value (the analogue of building the RSP tree and
    memoizing every mod).
  * ``propagate(state, new_inputs) -> (state, stats)`` — fully jitted
    change propagation: diff the inputs into per-block dirty masks
    (Algorithm-2 value cutoff at the leaves), push masks edge-wise through
    the reader index maps level by level, and recompute exactly the dirty
    blocks of each node, re-applying the value cutoff after every node so
    propagation dies as soon as recomputed values are bitwise unchanged.

Scheduling: nodes are grouped into *levels* (longest path from an input,
over data edges plus the S-composition control edges recorded by
``GraphBuilder.seq``).  Nodes within a level are independent by SP
structure — exactly the paper's guarantee that change propagation may
proceed in parallel under P nodes — so their masked recomputes execute in
one fused pass per level under jit (XLA sees a straight-line program with
no cross-node ordering inside a level).

Per node, per update, the runtime picks between two identical-result
regimes by dirty count (the TPU translation of the paper's observation
that from-scratch wins past a crossover update size, generalized from
``reduce.py``):

  * sparse — gather the <= max_sparse dirty blocks, recompute, scatter;
  * dense  — one masked pass over all blocks; elementwise/pair levels
    (map / zip_map / reduce_level) route through the Pallas dirty-tile
    kernel (``kernels.dirty_map``) when eligible, which skips clean tiles
    entirely via scalar-prefetched flags.

``stats['recomputed']`` counts recomputed blocks (the realized computation
distance W_delta), ``stats['affected']`` the value-changed blocks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import graph_ops
from .autotune import calibrated_max_sparse
from .dirtyset import DIRTY_REPS
from .graph import (ELEMENTWISE_KINDS, GNode, GraphBuilder, Handle,
                    level_schedule)

__all__ = ["CompiledGraph"]


def _feat_size(shape: Tuple[int, ...]) -> int:
    return int(math.prod(shape[1:]))


def _own_inputs(inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Copy numpy-backed inputs before dispatch.

    ``jnp.asarray`` of an aligned numpy buffer is zero-copy, and the
    jitted init/propagate consume it asynchronously — a caller mutating
    the buffer in place afterwards (the natural usage for an incremental
    API) would corrupt the stored old values.  ``jnp.array`` copies the
    numpy source synchronously; jax Arrays are immutable and pass
    through (a caller holding a zero-copy *view* must copy themselves —
    the standard JAX aliasing rule).
    """
    return {k: jnp.array(v) if isinstance(v, np.ndarray) else v
            for k, v in inputs.items()}


class CompiledGraph:
    def __init__(self, builder: GraphBuilder, *, max_sparse="auto",
                 use_pallas="auto", interpret: Optional[bool] = None,
                 pallas_tile: int = 8, dirty: str = "mask"):
        assert builder.inputs, "graph has no inputs"
        assert dirty in DIRTY_REPS, f"unknown dirty rep {dirty!r}"
        self.nodes: List[GNode] = list(builder.nodes)
        self.input_names: Dict[str, int] = dict(builder.inputs)
        self.outputs: List[int] = list(builder.outputs) or builder.sinks()
        self.dirty_rep = dirty
        self._dirty_cls = DIRTY_REPS[dirty]
        self.max_sparse = max_sparse
        # Per-node sparse budget: the old constant when given; otherwise
        # calibrated per level from a timed warmup (autotune.py) at the
        # first init, when the values' feature dims are known and the
        # measured payload matches the real per-block row width.
        if max_sparse in (None, "auto"):
            self._ks: Optional[List[int]] = None
        else:
            self._ks = [min(int(max_sparse), nd.num_blocks)
                        for nd in self.nodes]
        self.pallas_tile = int(pallas_tile)
        if use_pallas == "auto":
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret

        # ---- level schedule (data edges + seq control edges) ----------
        self.level_of, self.schedule = level_schedule(self.nodes)
        self.num_levels = len(self.schedule)
        # from-scratch work in blocks (every op node recomputes everything)
        self.total_blocks = sum(
            nd.num_blocks for nd in self.nodes if nd.kind != "input")

        self._init_fn = jax.jit(self._init_impl)
        self._prop_fn = jax.jit(self._propagate_impl)

    # ------------------------------------------------------------------
    # Initial run
    # ------------------------------------------------------------------
    def _init_impl(self, inputs: Dict[str, jax.Array]):
        values: List[Any] = [None] * len(self.nodes)
        for nd in self.nodes:
            if nd.kind == "input":
                values[nd.idx] = jnp.asarray(inputs[nd.name])
            else:
                parents = [values[d] for d in nd.deps]
                values[nd.idx] = graph_ops.forward(nd, self.nodes, parents)
        return {"v": tuple(values)}

    def init(self, inputs: Optional[Dict[str, jax.Array]] = None, **kw):
        inputs = {**(inputs or {}), **kw}
        assert set(inputs) == set(self.input_names), (
            f"inputs {sorted(inputs)} != declared {sorted(self.input_names)}")
        for name, idx in self.input_names.items():
            nd = self.nodes[idx]
            got = inputs[name].shape[0]
            assert got == nd.n, (
                f"input {name!r}: leading size {got}, traced with {nd.n}")
        state = self._init_fn(_own_inputs(inputs))
        if self._ks is None:             # auto crossover: calibrate once
            # escan always takes the dense path (_recompute), so its
            # crossover is dead — don't pay timed runs for it.
            self._ks = [
                0 if nd.kind in ("input", "escan") else
                calibrated_max_sparse(
                    nd.num_blocks,
                    nd.block * _feat_size(state["v"][nd.idx].shape))
                for nd in self.nodes]
        return state

    # ------------------------------------------------------------------
    # Accessors
    # ------------------------------------------------------------------
    def value(self, state, handle: Handle) -> jax.Array:
        return state["v"][handle.idx]

    def result(self, state, handle: Optional[Handle] = None) -> jax.Array:
        idx = self.outputs[0] if handle is None else handle.idx
        return state["v"][idx]

    # ------------------------------------------------------------------
    # Change propagation
    # ------------------------------------------------------------------
    def propagate(self, state, new_inputs: Dict[str, jax.Array]):
        """Jitted change propagation; omitted inputs are taken unchanged.

        Numpy inputs are copied before dispatch (see ``_own_inputs``);
        don't pass a zero-copy jax view (``jnp.asarray``) of a buffer you
        then mutate in place — the standard JAX aliasing rule.
        """
        unknown = set(new_inputs) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        assert self._ks is not None, "propagate() before init()"
        return self._prop_fn(state, _own_inputs(new_inputs))

    def _propagate_impl(self, state, new_inputs: Dict[str, jax.Array]):
        D = self._dirty_cls
        vals = list(state["v"])
        changed: List[Any] = [None] * len(self.nodes)   # DirtySets
        recomputed = jnp.int32(0)
        affected = jnp.int32(0)
        dirty_inputs = jnp.int32(0)

        for lvl in self.schedule:
            for idx in lvl:
                nd = self.nodes[idx]
                if nd.kind == "input":
                    old = vals[idx]
                    if nd.name in new_inputs:
                        new = jnp.asarray(new_inputs[nd.name]).astype(
                            old.dtype)
                        ch = D.from_diff(old, new, nd.block)
                        vals[idx] = new
                    else:
                        ch = D.none(nd.num_blocks)
                    changed[idx] = ch
                    dirty_inputs += ch.count()
                    continue

                dirty = graph_ops.edge_dirty(
                    nd, [changed[d] for d in nd.deps])
                parents = [vals[d] for d in nd.deps]
                old = vals[idx]
                new = self._recompute(nd, parents, old, dirty)
                ch = dirty.meet_diff(old, new, nd.block)
                vals[idx] = new
                changed[idx] = ch
                recomputed += dirty.count()
                affected += ch.count()

        stats = {"recomputed": recomputed, "affected": affected,
                 "dirty_inputs": dirty_inputs}
        return {"v": tuple(vals)}, stats

    # ------------------------------------------------------------------
    def _recompute(self, nd: GNode, parents, old, dirty):
        mask = dirty.to_mask()
        if nd.kind == "escan":
            # nb cheap elements; the masked dense pass IS the fast path.
            return graph_ops.dense_update(nd, self.nodes, parents, old, mask)
        k = self._ks[nd.idx]
        count = dirty.count()

        def sparse(_):
            return graph_ops.sparse_update(
                nd, self.nodes, parents, old, mask, k)

        def dense(_):
            return self._dense(nd, parents, old, mask)

        return jax.lax.cond(count <= k, sparse, dense, None)

    def _dense(self, nd: GNode, parents, old, dirty):
        if self.use_pallas and self._pallas_eligible(nd, parents, old):
            return self._pallas_dense(nd, parents, old, dirty)
        return graph_ops.dense_update(nd, self.nodes, parents, old, dirty)

    # ------------------------------------------------------------------
    # Pallas dirty-tile routing (elementwise / pair levels)
    # ------------------------------------------------------------------
    def _pallas_eligible(self, nd: GNode, parents, old) -> bool:
        if nd.kind not in ELEMENTWISE_KINDS:
            return False
        if nd.num_blocks % self.pallas_tile != 0:
            return False
        if nd.kind == "reduce_level" and (
                self.nodes[nd.deps[0]].num_blocks != 2 * nd.num_blocks):
            return False                 # identity-padded odd level
        return all(p.dtype == old.dtype for p in parents)

    def _pallas_dense(self, nd: GNode, parents, old, dirty):
        from repro.kernels.ops import dirty_map

        nb = nd.num_blocks
        w_out = nd.block * _feat_size(old.shape)
        rows, shapes = [], []
        for d, val in zip(nd.deps, parents):
            p = self.nodes[d]
            if nd.kind == "reduce_level":
                bshape = (2,) + val.shape[1:]          # pair per out block
            else:
                bshape = (p.block,) + val.shape[1:]
            rows.append(val.reshape(nb, int(math.prod(bshape))))
            shapes.append(bshape)

        def tile_fn(*tiles):
            t = tiles[0].shape[0]
            blocks = [x.reshape((t,) + s) for x, s in zip(tiles, shapes)]
            if nd.kind == "reduce_level":
                raw = nd.op(blocks[0][:, 0], blocks[0][:, 1])
            else:
                raw = jax.vmap(nd.fn)(*blocks)
            return raw.reshape(t, w_out)

        out = dirty_map(tile_fn, rows, old.reshape(nb, w_out), dirty,
                        block=self.pallas_tile, interpret=self.interpret)
        # The kernel recomputes *whole* dirty tiles, including their clean
        # blocks.  By determinism those recompute to equal values — but
        # only modulo compiled-kernel-vs-XLA fusion differences (FMA can
        # shift a ulp).  Mask them back to `old` so clean blocks stay
        # bitwise stable and the changed-mask cutoff remains sound.
        old_rows = old.reshape(nb, w_out)
        out = jnp.where(dirty[:, None], out, old_rows)
        return out.reshape(old.shape)
