"""Level scheduler + jit-compiled change propagation for traced SP-dags.

``CompiledGraph`` takes a ``GraphBuilder`` trace and produces:

  * ``init(**inputs) -> state`` — the initial run (jitted): forward every
    node, store every value (the analogue of building the RSP tree and
    memoizing every mod).
  * ``propagate(state, new_inputs) -> (state, stats)`` — fully jitted
    change propagation: diff the inputs into per-block dirty masks
    (Algorithm-2 value cutoff at the leaves), push masks edge-wise through
    the reader index maps level by level, and recompute exactly the dirty
    blocks of each node, re-applying the value cutoff after every node so
    propagation dies as soon as recomputed values are bitwise unchanged.

Scheduling: nodes are grouped into *levels* (longest path from an input,
over data edges plus the S-composition control edges recorded by
``GraphBuilder.seq``).  Nodes within a level are independent by SP
structure — exactly the paper's guarantee that change propagation may
proceed in parallel under P nodes — so their masked recomputes execute in
one fused pass per level under jit (XLA sees a straight-line program with
no cross-node ordering inside a level).

The propagation *latency* model (DESIGN.md §Propagation-cost-model) is
what shapes the hot path; a small edit must beat from-scratch in
wall-clock, not just in blocks recomputed:

  * **donated, in-place state** — the state tuple is donated to the
    jitted propagate (``donate_argnums=0``), so untouched node values
    alias straight through to the output and the sparse regime's scatter
    updates the node's buffer in place.  Without donation every update
    paid one full copy of every node's value (O(total state) memcpy —
    the dominant fixed cost at medium sizes).
  * **lane-local value cutoff** — the sparse regime compares only the
    <= k recomputed lanes against their old values (O(k) + an O(nb)
    scatter), never a full O(n) array compare.
  * **whole-level skip** — each level's recomputes run under one
    ``lax.cond`` on the level's aggregate dirty count: once the cutoff
    kills propagation, every remaining level costs one scalar compare.
  * **level packing** — same-kind nodes of a level that share the same
    per-block function (common under ``par``: parallel reduce trees,
    replicated map pipelines) are recomputed by ONE batched
    gather -> fn -> scatter, one kernel launch per level instead of per
    node.
  * **block-skip carries** — ``escan`` and carry-causal nodes reseed
    from the cached carry state of the previous run instead of
    rescanning their prefix (``graph_ops.escan_block_skip`` /
    ``causal_carry_refold``; the Pallas tile-skipping variant is
    ``kernels.dirty_causal``), gated to exactly-associative dtypes so
    the bitwise cutoff stays sound.
  * **dirty-signature plan cache** — planned mode quantizes the mark
    counts into a signature and memoizes the frozen plan + executable
    behind an LRU (``plancache.py``); gather indices come from the mark
    masks on device (``graph_ops.mask_indices``), so a signature hit
    performs zero host plan-freeze syncs.
  * **mesh sharding** — ``mesh=`` partitions every node's block axis
    into per-device chunks and runs the planned executable as one
    ``shard_map`` program with per-shard dirty sets and collectives
    only at level barriers (``shard_ops.py``; bitwise-identical to
    single-device, see DESIGN.md §Sharded-propagation).

Per node, per update, the runtime picks between two identical-result
regimes by dirty count (the TPU translation of the paper's observation
that from-scratch wins past a crossover update size, generalized from
``reduce.py``):

  * sparse — gather the <= max_sparse dirty blocks, recompute, scatter;
  * dense  — one masked pass over all blocks; elementwise/pair/stencil
    levels route through the Pallas dirty-tile kernel
    (``kernels.dirty_map``) when eligible, which skips clean tiles
    entirely via scalar-prefetched flags.

``stats['recomputed']`` counts recomputed blocks (the realized computation
distance W_delta), ``stats['affected']`` the value-changed blocks.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import syncpoints
from repro.obs.record import LevelRecord, PhaseSpan, PropagationRecord
from repro.obs.recorder import regime_label

from . import graph_ops
from .autotune import calibrated_max_sparse
from .dirtyset import DIRTY_REPS
from .graph import (ELEMENTWISE_KINDS, GNode, GraphBuilder, Handle,
                    level_schedule)
from .plancache import PlanCache, PlanEntry, next_pow2

__all__ = ["CompiledGraph", "PendingUpdate"]


@dataclasses.dataclass
class PendingUpdate:
    """A marked-but-not-executed update: the owned inputs, the mark
    masks, and the frozen quantized plan (the dirty signature).

    The two-phase currency of the serving layer (``repro.serve``):
    ``CompiledGraph.plan_update`` produces one without touching the
    state, and equal ``plan`` fields across *different sessions* of one
    trace mean the updates are batch-compatible — they dispatch through
    one plan-cache entry, so a batch pays the executable freeze at most
    once."""

    inputs: Dict[str, jax.Array]
    in_masks: Dict[str, jax.Array]
    node_masks: Dict[str, jax.Array]
    counts: np.ndarray
    plan: Tuple[Any, ...]


def _feat_size(shape: Tuple[int, ...]) -> int:
    return int(math.prod(shape[1:]))


def _inject_device_loss() -> None:
    """Chaos site ``device.loss``: fired before every sharded (mesh)
    propagate dispatch — the stand-in for a shard/device failure, whose
    recovery path is checkpoint-restore onto a smaller mesh
    (``runtime.elastic.remesh_shards`` + ``Supervisor.remesh_fn``).
    Late import: jaxsac must not depend on repro.runtime at load."""
    from repro.runtime.faults import inject

    inject("device.loss")


def _own_inputs(inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Copy numpy-backed inputs before dispatch.

    ``jnp.asarray`` of an aligned numpy buffer is zero-copy, and the
    jitted init/propagate consume it asynchronously — a caller mutating
    the buffer in place afterwards (the natural usage for an incremental
    API) would corrupt the stored old values.  ``jnp.array`` copies the
    numpy source synchronously; jax Arrays are immutable and pass
    through (a caller holding a zero-copy *view* must copy themselves —
    the standard JAX aliasing rule).
    """
    return {k: jnp.array(v) if isinstance(v, np.ndarray) else v
            for k, v in inputs.items()}


def _is_carry(nd: GNode) -> bool:
    return nd.kind == "causal" and nd.op is not None


class CompiledGraph:
    # Nodes with at most this many blocks always take the plain dense
    # masked pass: recomputing every row is cheaper than the sparse
    # regime's gather/scatter op chain (see _recompute).
    TINY_NB = 64

    def __init__(self, builder: GraphBuilder, *, max_sparse="auto",
                 use_pallas="auto", interpret: Optional[bool] = None,
                 pallas_tile: int = 8, dirty: str = "mask",
                 donate: bool = True, block_skip="auto",
                 level_skip: bool = True, plan: bool = True,
                 mesh=None, plan_cache: int = 64):
        assert builder.inputs, "graph has no inputs"
        assert dirty in DIRTY_REPS, f"unknown dirty rep {dirty!r}"
        assert block_skip in ("auto", True, False), block_skip
        self.nodes: List[GNode] = list(builder.nodes)
        self.input_names: Dict[str, int] = dict(builder.inputs)
        self.outputs: List[int] = list(builder.outputs) or builder.sinks()
        self.dirty_rep = dirty
        self._dirty_cls = DIRTY_REPS[dirty]
        self.max_sparse = max_sparse
        # Per-node sparse budget: the old constant when given; otherwise
        # calibrated per level from a timed warmup (autotune.py) at the
        # first init, when the values' feature dims are known and the
        # measured payload matches the real per-block row width.
        if max_sparse in (None, "auto"):
            self._ks: Optional[List[int]] = None
        else:
            self._ks = [min(int(max_sparse), nd.num_blocks)
                        for nd in self.nodes]
        self.pallas_tile = int(pallas_tile)
        if use_pallas == "auto":
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = interpret
        # ---- mesh sharding (see shard_ops.py / DESIGN.md) -------------
        self.mesh = None
        self.shard_axis: Optional[str] = None
        self.num_shards = 1
        if mesh is not None:
            from repro.shardlib import block_mesh

            if isinstance(mesh, int):
                mesh = block_mesh(mesh)
            axes = tuple(mesh.axis_names)
            assert len(axes) == 1, (
                f"CompiledGraph shards the block axis over a ONE-axis "
                f"mesh; got axes {axes}")
            self.mesh = mesh
            self.shard_axis = axes[0]
            self.num_shards = int(np.prod(mesh.devices.shape))
            # Pallas dirty-tile routing inside the shard_map body is
            # not wired up; the sharded executable uses the XLA paths.
            self.use_pallas = False
        self.donate = bool(donate)
        self.block_skip = block_skip
        self.level_skip = bool(level_skip)
        # Carry-causal nodes cache their per-block carry states in the
        # propagation state (state["c"]), keyed by node idx.
        self.carry_nodes: Tuple[int, ...] = tuple(
            nd.idx for nd in self.nodes if _is_carry(nd))

        # ---- level schedule (data edges + seq control edges) ----------
        self.level_of, self.schedule = level_schedule(self.nodes)
        self.num_levels = len(self.schedule)
        # from-scratch work in blocks (every op node recomputes everything)
        self.total_blocks = sum(
            nd.num_blocks for nd in self.nodes if nd.kind != "input")
        # Same-kind level packing: nodes of one level sharing the same
        # per-block function and block geometry batch into one
        # gather->fn->scatter (keys are static; shapes re-checked at
        # trace time when the real feature dims are known).
        self._level_groups = [self._pack_level(lvl) for lvl in self.schedule]

        self.plan_mode = bool(plan)
        self._init_fn = jax.jit(self._init_impl)
        # Legacy single-executable propagate (runtime lax.cond regimes);
        # kept as the plan=False path and the planned mode's oracle.
        self._prop_fn = jax.jit(self._propagate_impl,
                                donate_argnums=(0,) if self.donate else ())
        # Under a mesh the legacy oracle runs GSPMD-partitioned over the
        # sharded state without donation (input/output layouts are the
        # compiler's choice there, so aliasing cannot be guaranteed).
        self._prop_mesh_fn = (jax.jit(self._propagate_impl)
                              if self.mesh is not None else None)
        # Planned mode: mark jit (reads state, tiny outputs) + one
        # recompute executable per distinct quantized plan, memoized in
        # the dirty-signature LRU (each entry owns its jit wrapper, so
        # eviction really frees the executable).
        self._mark_fn = jax.jit(self._mark_impl)
        self._plan_cache = PlanCache(cap=plan_cache)
        self._sharder = None             # built at init under a mesh
        # Non-donating propagate for the COW forest's fallback paths
        # (built lazily) and the abstract state spec recorded at first
        # init (checkpoint restore needs the leaf shapes/dtypes without
        # a live state in hand).
        self._prop_copy_fn = None
        self._abstract = None
        # ---- observability (repro.obs) --------------------------------
        # Recorder is OFF by default: with no recorder attached the
        # planned path takes zero extra host syncs (the only host read
        # stays the mark-counts read, now routed through
        # obs.syncpoints so tests can assert exactly that).
        self._recorder = None
        # Deep-mode per-level executables, keyed (plan, level).  Non-
        # donating: deep mode trades the in-place update for per-level
        # fences and is never the benchmarked path.
        self._deep_fns: Dict[Any, Any] = {}
        self._deep_boundary_fn = jax.jit(self._deep_boundary_impl)

    # ------------------------------------------------------------------
    def _pack_level(self, lvl: Sequence[int]) -> List[List[int]]:
        """Group a level's op nodes by batchable identity (same kind,
        same traced fn/op object, same block geometry)."""
        groups: Dict[Any, List[int]] = {}
        order: List[Any] = []
        for idx in lvl:
            nd = self.nodes[idx]
            if nd.kind in ("map", "zip_map", "reduce_level"):
                parents_meta = tuple(
                    (self.nodes[d].num_blocks, self.nodes[d].block)
                    for d in nd.deps)
                try:
                    ia = np.asarray(nd.identity)
                    # Bitwise identity key: repr would truncate/summarize
                    # and could falsely pack trees whose identities
                    # differ below print precision.
                    ident_key = (str(ia.dtype), ia.shape, ia.tobytes())
                except Exception:       # pragma: no cover - exotic identity
                    ident_key = id(nd.identity)
                key = (nd.kind, id(nd.fn), id(nd.op), ident_key,
                       nd.num_blocks, nd.block, parents_meta)
            else:
                key = ("solo", idx)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(idx)
        return [groups[k] for k in order]

    # ------------------------------------------------------------------
    # Initial run
    # ------------------------------------------------------------------
    def _init_impl(self, inputs: Dict[str, jax.Array]):
        values: List[Any] = [None] * len(self.nodes)
        carries: Dict[str, jax.Array] = {}
        for nd in self.nodes:
            if nd.kind == "input":
                values[nd.idx] = jnp.asarray(inputs[nd.name])
            elif _is_carry(nd):
                parent = values[nd.deps[0]]
                states = graph_ops.causal_carry_states(nd, self.nodes, parent)
                carries[str(nd.idx)] = states
                p = self.nodes[nd.deps[0]]
                xb = parent.reshape((p.num_blocks, p.block) + parent.shape[1:])
                raw = jax.vmap(nd.finalize)(states, xb)
                values[nd.idx] = graph_ops._pack(nd, raw)
            else:
                parents = [values[d] for d in nd.deps]
                values[nd.idx] = graph_ops.forward(nd, self.nodes, parents)
        return {"v": tuple(values), "c": carries}

    def init(self, inputs: Optional[Dict[str, jax.Array]] = None, **kw):
        inputs = {**(inputs or {}), **kw}
        assert set(inputs) == set(self.input_names), (
            f"inputs {sorted(inputs)} != declared {sorted(self.input_names)}")
        for name, idx in self.input_names.items():
            nd = self.nodes[idx]
            got = inputs[name].shape[0]
            assert got == nd.n, (
                f"input {name!r}: leading size {got}, traced with {nd.n}")
        state = self._init_fn(_own_inputs(inputs))
        if self._abstract is None:
            self._abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        if self._ks is None:             # auto crossover: calibrate once
            # escan always takes a dense/block-skip carry pass, so its
            # crossover is dead — don't pay timed runs for it.
            self._ks = [
                0 if nd.kind in ("input", "escan") else
                calibrated_max_sparse(
                    nd.num_blocks,
                    nd.block * _feat_size(state["v"][nd.idx].shape))
                for nd in self.nodes]
        if self.mesh is not None:
            # The shard layout needs the realized dtypes (the carry /
            # escan exact-dtype gate), so it is decided here, at first
            # init, and the state laid out over the mesh in one
            # device_put.
            if self._sharder is None:
                from .shard_ops import ShardedPropagator

                self._sharder = ShardedPropagator(self, state)
            state = self._sharder.place(state)
        return state

    # ------------------------------------------------------------------
    # Accessors
    # ------------------------------------------------------------------
    def value(self, state, handle: Handle) -> jax.Array:
        """Read a node's value.  Under ``donate=True`` the returned array
        aliases the live state: it becomes invalid once this state is
        passed to a later ``propagate`` (copy first to keep it)."""
        return state["v"][handle.idx]

    def result(self, state, handle: Optional[Handle] = None) -> jax.Array:
        idx = self.outputs[0] if handle is None else handle.idx
        return state["v"][idx]

    def abstract_state(self):
        """ShapeDtypeStruct pytree of the propagation state (recorded at
        first ``init``) — the restore spec for checkpointed sessions."""
        assert self._abstract is not None, "abstract_state() before init()"
        return self._abstract

    def attach_recorder(self, recorder) -> None:
        """Attach (or detach with ``None``) a ``PropagationRecorder``;
        every subsequent ``propagate`` emits one ``PropagationRecord``."""
        self._recorder = recorder
        if recorder is None:
            self._plan_cache.on_event = None
        else:
            reg = recorder.registry
            self._plan_cache.on_event = (
                lambda kind: reg.counter(f"plan_cache.{kind}_events").inc())

    def plan_cache_snapshot(self) -> Dict[str, int]:
        return self._plan_cache.snapshot()

    # ------------------------------------------------------------------
    # Change propagation
    # ------------------------------------------------------------------
    def propagate(self, state, new_inputs: Dict[str, jax.Array]):
        """Jitted change propagation; omitted inputs are taken unchanged.

        Numpy inputs are copied before dispatch (see ``_own_inputs``);
        don't pass a zero-copy jax view (``jnp.asarray``) of a buffer you
        then mutate in place — the standard JAX aliasing rule.

        Under ``donate=True`` (the default) ``state`` is DONATED: its
        buffers are reused in place for the returned state, so the passed
        state (and any arrays previously read out of it) must not be used
        afterwards.  Chain states linearly — exactly what the stateful
        facades (``GraphHandle``, ``IncrementalReduce``) do.
        """
        unknown = set(new_inputs) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        assert self._ks is not None, "propagate() before init()"
        if "c" not in state:             # pre-donation states (old pickles)
            state = {**state, "c": {}}
        inputs = _own_inputs(new_inputs)
        traced = any(isinstance(leaf, jax.core.Tracer)
                     for leaf in jax.tree_util.tree_leaves((state, inputs)))
        rec = self._recorder
        if not self.plan_mode or traced:
            # Under an outer jit (propagate composed into a caller's
            # traced function) the planned mode's host sync is
            # impossible — and unnecessary: the legacy cond executable
            # inlines into the caller's trace.  Traced calls are never
            # recorded (there is no host boundary to time).
            if traced:
                return self._prop_fn(state, inputs)
            t0 = rec.clock() if rec is not None else 0.0
            fn = self._prop_mesh_fn if self.mesh is not None else self._prop_fn
            if self.mesh is not None:
                _inject_device_loss()
            new_state, stats = fn(state, inputs)
            if rec is not None:
                if rec.mode == "deep":
                    syncpoints.fence(new_state, "execute")
                rec.emit(self._build_record(
                    rec, plan=None, counts_np=None, hit=None,
                    t_start=t0, t_mark=t0, t_plan=t0, t_end=rec.clock(),
                    stats=stats, level_ms=None, input_key=frozenset(inputs)))
            return new_state, stats
        # Two-phase planned propagation (the paper's mark-then-propagate,
        # made executable-shaped): a small jitted MARK pass pushes the
        # input diff through the reader maps WITHOUT the value cutoff —
        # a sound over-approximation of every node's dirty count — the
        # host reads the counts (one tiny device sync: the only host
        # read an update ever makes) and QUANTIZES them into the dirty
        # signature = the per-node skip / sparse-bucket / dense plan.
        # The signature keys an LRU of plan-specialized executables
        # (plancache.py): a hit dispatches straight into the cached
        # executable — sparse gather indices are extracted on device
        # from the mark masks (graph_ops.mask_indices), so no plan is
        # re-frozen and the masks never leave the device; a miss builds
        # the executable once.  The executable runs with no in-graph
        # branching at all: clean nodes simply don't appear in it, and
        # every sparse scatter updates the donated state in place
        # (see DESIGN.md §Propagation-cost-model).
        t_start = rec.clock() if rec is not None else 0.0
        mark = (self._sharder.mark if self.mesh is not None
                else self._mark_fn)
        masks, counts, node_masks = mark(state, inputs)
        # THE host sync of the planned path.  Routed through
        # obs.syncpoints so the zero-extra-syncs guarantee of counters
        # mode is testable: with tracing on, a hooked run must see this
        # one read and nothing else.
        counts_np = syncpoints.host_read(counts, "mark_counts")
        t_mark = rec.clock() if rec is not None else 0.0
        plan = self._make_plan(counts_np, frozenset(inputs))
        entry = self._plan_cache.lookup(plan)
        hit = entry is not None
        if entry is None:
            if self.mesh is not None:
                fn = self._sharder.planned_fn(plan)
            else:
                fn = jax.jit(
                    functools.partial(self._prop_planned_impl, plan=plan),
                    donate_argnums=(0,) if self.donate else ())
            entry = self._plan_cache.insert(plan, PlanEntry(plan, fn))
        t_plan = rec.clock() if rec is not None else 0.0
        deep = rec is not None and rec.mode == "deep"
        level_ms = None
        if deep and self.mesh is None:
            # Deep mode: per-level executables with a fence after each
            # level — real per-level wall-clock, at the cost of losing
            # donation and cross-level fusion.  Same math per level
            # (_planned_level), so stats stay bitwise-identical.
            new_state, stats, level_ms = self._propagate_deep(
                state, inputs, masks, node_masks, plan, rec)
        else:
            if self.mesh is not None:
                _inject_device_loss()
            new_state, stats = entry.fn(state, inputs, masks, node_masks)
            if deep:                     # mesh: fence the one executable
                syncpoints.fence(new_state, "execute")
        stats = {**stats, "plan_cache": self._plan_cache.snapshot()}
        if rec is not None:
            rec.emit(self._build_record(
                rec, plan=plan, counts_np=counts_np, hit=hit,
                t_start=t_start, t_mark=t_mark, t_plan=t_plan,
                t_end=rec.clock(), stats=stats, level_ms=level_ms,
                input_key=frozenset(inputs)))
        return new_state, stats

    def _build_record(self, rec, *, plan, counts_np, hit, t_start, t_mark,
                      t_plan, t_end, stats, level_ms, input_key):
        """One PropagationRecord from host-known values only: counts_np
        is already on the host, stats values stay device-resident until
        the record is finalized by a reader — building and emitting the
        record never syncs."""
        deep = rec.mode == "deep"
        phases = [PhaseSpan("execute", t_plan, t_end - t_plan)]
        if plan is not None:             # planned path: all three phases
            phases = [PhaseSpan("mark", t_start, t_mark - t_start),
                      PhaseSpan("plan", t_mark, t_plan - t_mark)] + phases
        levels = []
        for li, lvl in enumerate(self.schedule):
            ops = [i for i in lvl if self.nodes[i].kind != "input"]
            regimes: Dict[str, int] = {}
            for i in lvl:
                lab = (regime_label(plan[i]) if plan is not None
                       else "cond")
                regimes[lab] = regimes.get(lab, 0) + 1
            levels.append(LevelRecord(
                level=li, nodes=len(ops), regimes=regimes,
                dirty=(int(sum(int(counts_np[i]) for i in lvl))
                       if counts_np is not None else None),
                ms=(level_ms[li] if level_ms is not None else None)))
        counters = {k: stats[k] for k in
                    ("recomputed", "affected", "dirty_inputs",
                     "rec_per_level", "aff_per_level",
                     "recomputed_per_shard") if k in stats}
        if plan is not None:
            counters["plan_hit"] = int(bool(hit))
        collectives = None
        if self._sharder is not None:
            collectives = {
                "mark": dict(self._sharder.mark_tallies.get(input_key, {})),
                "propagate": dict(self._sharder.tallies.get(plan, {}))
                if plan is not None else {}}
        return PropagationRecord(
            substrate="graph", seq=rec.next_seq(), mode=rec.mode,
            t_start=t_start, phases=phases, levels=levels,
            counters=counters, plan_cache=stats.get("plan_cache"),
            collectives=collectives, shards=self.num_shards,
            fenced=deep and self.mesh is None)

    def _mark_impl(self, state, new_inputs: Dict[str, jax.Array]):
        """Mark phase: exact per-block diffs at the inputs, pure mask
        pushing above (no recomputes, no value cutoff — over-approximate
        and cheap: O(num_blocks) per node).  Returns the input masks (the
        recompute phase reuses them instead of re-diffing), every node's
        dirty-count upper bound, and the per-node dirty masks the host
        turns into gather indices (``np.flatnonzero`` on a few-KB mask is
        microseconds, while ``jnp.nonzero`` inside a jit lowers to a full
        sort on CPU and dominates the whole propagate)."""
        D = self._dirty_cls
        dirty: List[Any] = [None] * len(self.nodes)
        masks: Dict[str, jax.Array] = {}
        node_masks: Dict[str, jax.Array] = {}
        for nd in self.nodes:
            if nd.kind == "input":
                if nd.name in new_inputs:
                    new = jnp.asarray(new_inputs[nd.name]).astype(
                        state["v"][nd.idx].dtype)
                    ch = D.from_diff(state["v"][nd.idx], new, nd.block)
                    masks[nd.name] = ch.to_mask()
                else:
                    ch = D.none(nd.num_blocks)
                dirty[nd.idx] = ch
            else:
                dirty[nd.idx] = graph_ops.edge_dirty(
                    nd, [dirty[d] for d in nd.deps],
                    [state["v"][d] for d in nd.deps])
                node_masks[str(nd.idx)] = dirty[nd.idx].to_mask()
        counts = jnp.stack([dirty[nd.idx].count() for nd in self.nodes])
        return masks, counts, node_masks

    def _make_plan(self, counts: np.ndarray, provided: frozenset):
        """Freeze the quantized per-node plan — the dirty *signature*
        the plan cache keys on.  ``counts`` over-approximates the
        post-cutoff dirty sets, so "skip" (count 0) is sound and a
        sparse budget can never under-gather; sparse counts round up to
        the next power of two (the node's gather width for this plan),
        so nearby edit sizes share one signature and one executable."""
        plan = []
        for nd in self.nodes:
            c = int(counts[nd.idx])
            if nd.kind == "input":
                plan.append("update" if c and nd.name in provided
                            else "skip")
            elif c == 0:
                plan.append("skip")
            elif nd.kind == "escan":
                plan.append("live")      # its own carry-pass machinery
            elif (nd.num_blocks <= self.TINY_NB
                  or c > self._ks[nd.idx]):
                plan.append("dense")
            else:
                plan.append(("sparse", min(next_pow2(c), self._ks[nd.idx],
                                           nd.num_blocks)))
        return tuple(plan)

    def _run_planned(self, vals, carries, new_inputs, in_masks,
                     node_masks, plan):
        """Drive every level of the plan-specialized recompute, mutating
        ``vals`` / ``carries`` in place, and return the stats dict.
        Shared verbatim by the whole-state executable
        (``_prop_planned_impl``) and the split donated/kept COW
        executable (``_prop_cow_impl``), so forest propagation is the
        same math by construction."""
        changed: List[Any] = [None] * len(self.nodes)
        rec_lvls: List[jax.Array] = []
        aff_lvls: List[jax.Array] = []
        recomputed = jnp.int32(0)
        affected = jnp.int32(0)
        dirty_inputs = jnp.int32(0)

        for li in range(self.num_levels):
            r, a, di = self._planned_level(
                li, vals, carries, changed, new_inputs, in_masks,
                node_masks, plan)
            rec_lvls.append(r)
            aff_lvls.append(a)
            # int32 adds are associative, so per-level partial sums then
            # a total is bitwise-identical to the old running sum.
            recomputed += r
            affected += a
            dirty_inputs += di

        return {"recomputed": recomputed, "affected": affected,
                "dirty_inputs": dirty_inputs,
                "rec_per_level": jnp.stack(rec_lvls),
                "aff_per_level": jnp.stack(aff_lvls),
                **self._boundary_stats(changed)}

    def _prop_planned_impl(self, state, new_inputs, in_masks, node_masks,
                           plan):
        """Plan-specialized recompute: one straight-line executable per
        distinct plan (each owned by its plan-cache entry).  Skipped
        nodes pass through untouched; nothing branches at runtime, and
        sparse gather indices come from the mark masks on device
        (``mask_indices``), never from a host read."""
        vals = list(state["v"])
        carries = dict(state["c"])
        stats = self._run_planned(vals, carries, new_inputs, in_masks,
                                  node_masks, plan)
        return {"v": tuple(vals), "c": carries}, stats

    # ------------------------------------------------------------------
    # Two-phase / copy-on-write propagation (the serving layer's API:
    # repro.serve.forest drives these)
    # ------------------------------------------------------------------
    def plan_update(self, state, new_inputs) -> Optional[PendingUpdate]:
        """Phase 1 of a split update: run the mark pass and freeze the
        quantized plan WITHOUT touching the state (the mark jit neither
        donates nor writes, so it is safe on a state whose buffers are
        aliased by other forest nodes).  Returns a ``PendingUpdate`` the
        caller executes later — or ``None`` when this compiled graph has
        no single-device planned path (``plan=False`` or ``mesh=``) and
        the caller must fall back to ``propagate_copy``."""
        unknown = set(new_inputs) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        assert self._ks is not None, "plan_update() before init()"
        if not self.plan_mode or self.mesh is not None:
            return None
        inputs = _own_inputs(new_inputs)
        masks, counts, node_masks = self._mark_fn(state, inputs)
        counts_np = syncpoints.host_read(counts, "mark_counts")
        plan = self._make_plan(counts_np, frozenset(inputs))
        return PendingUpdate(inputs=inputs, in_masks=masks,
                             node_masks=node_masks, counts=counts_np,
                             plan=plan)

    def cow_touched_keys(self, plan) -> Tuple[Tuple[str, ...],
                                              Tuple[str, ...]]:
        """``(donated, touched)`` leaf keys for ``plan`` over the flat
        leaf namespace ``"v<i>"`` (node values) / ``"c<i>"`` (carry
        caches).  ``touched`` is every leaf the plan writes — the leaves
        a forest propagate must own exclusively and the executable's
        outputs; ``donated`` excludes updated *inputs*, whose old value
        is only read (the new value arrives via ``new_inputs``), so a
        shared input leaf is never copied just to be overwritten."""
        donated: List[str] = []
        touched: List[str] = []
        for i, nd in enumerate(self.nodes):
            if plan[i] == "skip":
                continue
            touched.append(f"v{i}")
            if nd.kind != "input":
                donated.append(f"v{i}")
            if _is_carry(nd):
                touched.append(f"c{i}")
                donated.append(f"c{i}")
        return tuple(donated), tuple(touched)

    def cow_entry(self, plan) -> Tuple[PlanEntry, bool]:
        """``(entry, hit)`` — the plan-cache entry of the split
        donated/kept COW executable for ``plan``, compiled on miss.  COW
        entries share the LRU with the whole-state entries under a
        distinct key, so forked sessions of one handle share frozen
        plans exactly like repeated edits on one state do."""
        key = ("cow", plan)
        entry = self._plan_cache.lookup(key)
        hit = entry is not None
        if entry is None:
            fn = jax.jit(functools.partial(self._prop_cow_impl, plan=plan),
                         donate_argnums=(0,))
            entry = self._plan_cache.insert(key, PlanEntry(plan, fn))
        return entry, hit

    def _prop_cow_impl(self, donated, kept, new_inputs, in_masks,
                       node_masks, *, plan):
        """Split-state planned recompute for the COW forest: ``donated``
        holds exactly the leaves the plan scatters into (donated, so the
        update stays in place), ``kept`` every other leaf, passed
        read-only — their python arrays stay live in the caller's state,
        which is what lets forest nodes alias them freely.  Returns only
        the touched leaves: untouched ones never cross the executable,
        so a small edit moves O(changed nodes) buffers, not O(state)."""
        leaves = {**kept, **donated}
        vals: List[Any] = [leaves[f"v{i}"] for i in range(len(self.nodes))]
        carries: Dict[str, jax.Array] = {
            str(i): leaves[f"c{i}"] for i in self.carry_nodes}
        stats = self._run_planned(vals, carries, new_inputs, in_masks,
                                  node_masks, plan)
        _, touched = self.cow_touched_keys(plan)
        out = {key: (carries[key[1:]] if key[0] == "c"
                     else vals[int(key[1:])])
               for key in touched}
        return out, stats

    def propagate_copy(self, state, new_inputs):
        """Non-donating propagate: every output leaf is a fresh buffer
        and the passed state stays fully valid afterwards — the COW
        forest's fallback for compiled graphs without a single-device
        planned path (``plan=False``, or ``mesh=`` where the sharded
        planned executable donates whole-state, which an aliased forest
        state cannot allow)."""
        unknown = set(new_inputs) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        inputs = _own_inputs(new_inputs)
        if "c" not in state:
            state = {**state, "c": {}}
        if self.mesh is not None:
            _inject_device_loss()
            return self._prop_mesh_fn(state, inputs)
        if self._prop_copy_fn is None:
            self._prop_copy_fn = jax.jit(self._propagate_impl)
        return self._prop_copy_fn(state, inputs)

    def _planned_level(self, li: int, vals, carries, changed, new_inputs,
                       in_masks, node_masks, plan):
        """One level of the plan-specialized recompute.  Mutates
        ``vals`` / ``carries`` / ``changed`` in place and returns this
        level's (recomputed, affected, dirty_inputs) int32 deltas.
        Shared verbatim by the single planned executable and the
        deep-mode per-level executables, so trace modes are the same
        math by construction."""
        D = self._dirty_cls
        lvl = self.schedule[li]
        groups = self._level_groups[li]
        recomputed = jnp.int32(0)
        affected = jnp.int32(0)
        dirty_inputs = jnp.int32(0)

        for idx in lvl:
            nd = self.nodes[idx]
            if nd.kind != "input":
                continue
            if plan[idx] == "skip":
                changed[idx] = D.none(nd.num_blocks)
                continue
            old = vals[idx]
            new = jnp.asarray(new_inputs[nd.name]).astype(old.dtype)
            ch = self._from_mask(in_masks[nd.name])
            vals[idx] = new
            changed[idx] = ch
            dirty_inputs += ch.count()

        for grp in groups:
            if self.nodes[grp[0]].kind == "input":
                continue
            live = [i for i in grp if plan[i] != "skip"]
            for i in grp:
                if plan[i] == "skip":
                    changed[i] = D.none(self.nodes[i].num_blocks)
            if not live:
                continue
            dirties = {i: graph_ops.edge_dirty(
                self.nodes[i],
                [changed[d] for d in self.nodes[i].deps],
                [vals[d] for d in self.nodes[i].deps])
                for i in live}
            if (len(live) > 1
                    and all(isinstance(plan[i], tuple) for i in live)
                    and self._group_batchable(live, vals)):
                k = min(sum(plan[i][1] for i in live),
                        len(live) * self.nodes[live[0]].num_blocks)
                with jax.named_scope(self._scope(self.nodes[live[0]])):
                    gidx = graph_ops.mask_indices(
                        jnp.concatenate(
                            [node_masks[str(i)] for i in live]), k)
                    news, idxs, lcs = graph_ops.sparse_update_group(
                        [self.nodes[i] for i in live], self.nodes,
                        [[vals[d] for d in self.nodes[i].deps]
                         for i in live],
                        [vals[i] for i in live],
                        [dirties[i].to_mask() for i in live], k,
                        gidx=gidx)
                for i, nv, ix, lc in zip(live, news, idxs, lcs):
                    nb = self.nodes[i].num_blocks
                    vals[i] = nv
                    changed[i] = D.from_changed_lanes(ix, lc, nb)
                    recomputed += dirties[i].count()
                    affected += changed[i].count()
                continue
            for i in live:
                nd = self.nodes[i]
                parents = [vals[d] for d in nd.deps]
                sp = isinstance(plan[i], tuple)
                with jax.named_scope(self._scope(nd)):
                    nv, ch, st = self._recompute(
                        nd, parents, vals[i], dirties[i],
                        carries.get(str(i)),
                        regime="sparse" if sp else "dense",
                        idx=(graph_ops.mask_indices(node_masks[str(i)],
                                                    plan[i][1])
                             if sp else None))
                vals[i] = nv
                changed[i] = ch
                if st is not None:
                    carries[str(i)] = st
                recomputed += dirties[i].count()
                affected += ch.count()
        return recomputed, affected, dirty_inputs

    @staticmethod
    def _scope(nd: GNode) -> str:
        """HLO metadata scope for a node's recompute ops (zero runtime
        cost; names profiler rows after SP-dag nodes).  Sanitized to the
        charset ``jax.named_scope`` / HLO metadata accepts."""
        name = nd.name or nd.kind
        return "".join(c if (c.isalnum() or c in "._-") else "_"
                       for c in name) or "node"

    # ------------------------------------------------------------------
    # Deep-mode per-level driver (trace="deep")
    # ------------------------------------------------------------------
    def _deep_level_impl(self, vals, carries, ch_masks, new_inputs,
                         in_masks, node_masks, *, li, plan):
        """One level as a standalone executable: incoming changed sets
        arrive as per-node masks (lossless for both dirty reps — masks
        are exact for MaskDirty, and IntervalDirty is a contiguous hull,
        so from_mask(to_mask(d)) == d), the level body is the shared
        ``_planned_level``, and the level's own changed sets leave as
        masks for the next level."""
        D = self._dirty_cls
        vals = list(vals)
        carries = dict(carries)
        changed: List[Any] = [None] * len(self.nodes)
        for k, m in ch_masks.items():
            changed[int(k)] = D.from_mask(m)
        r, a, di = self._planned_level(
            li, vals, carries, changed, new_inputs, in_masks,
            node_masks, plan)
        out_masks = dict(ch_masks)
        for idx in self.schedule[li]:
            out_masks[str(idx)] = changed[idx].to_mask()
        return tuple(vals), carries, out_masks, (r, a, di)

    def _deep_boundary_impl(self, ch_masks):
        D = self._dirty_cls
        changed: List[Any] = [None] * len(self.nodes)
        for k, m in ch_masks.items():
            changed[int(k)] = D.from_mask(m)
        return self._boundary_stats(changed)

    def _deep_level_fn(self, plan, li: int):
        key = (plan, li)
        fn = self._deep_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                self._deep_level_impl, li=li, plan=plan))
            self._deep_fns[key] = fn
        return fn

    def _propagate_deep(self, state, inputs, in_masks, node_masks, plan,
                        rec):
        """Planned propagation, one fenced executable per level:
        TraceAnnotation-bracketed dispatch + block_until_ready gives the
        real per-level wall-clock the profile view shows.  Values cross
        level boundaries unfused and undonated — deep mode is the
        diagnostic path, not the fast path."""
        vals, carries = state["v"], state["c"]
        ch_masks: Dict[str, jax.Array] = {}
        recs: List[jax.Array] = []
        affs: List[jax.Array] = []
        level_ms: List[float] = []
        di_total = None
        for li in range(self.num_levels):
            t0 = rec.clock()
            with jax.profiler.TraceAnnotation(f"propagate/L{li}"):
                vals, carries, ch_masks, (r, a, di) = self._deep_level_fn(
                    plan, li)(vals, carries, ch_masks, inputs, in_masks,
                              node_masks)
                syncpoints.fence((vals, r, a), f"level_{li}")
            level_ms.append((rec.clock() - t0) * 1e3)
            recs.append(r)
            affs.append(a)
            di_total = di if di_total is None else di_total + di
        rec_v = jnp.stack(recs)
        aff_v = jnp.stack(affs)
        stats = {"recomputed": jnp.sum(rec_v), "affected": jnp.sum(aff_v),
                 "dirty_inputs": di_total,
                 "rec_per_level": rec_v, "aff_per_level": aff_v,
                 **self._deep_boundary_fn(ch_masks)}
        return {"v": tuple(vals), "c": dict(carries)}, stats, level_ms

    def _boundary_stats(self, changed: List[Any]) -> Dict[str, Any]:
        """Per-output changed masks and per-input dirty counts — the
        boundary currency of the hybrid runtime (sac/hybrid.py): an
        embedding skeleton re-runs a downstream reader / fragment only
        for outputs whose mask is non-empty, and attributes
        ``dirty_inputs`` to real program inputs without re-diffing."""
        return {
            "out_changed": {str(i): changed[i].to_mask()
                            for i in self.outputs},
            "in_dirty": {name: changed[idx].count()
                         for name, idx in self.input_names.items()},
        }

    def _from_mask(self, mask: jax.Array):
        return self._dirty_cls.from_mask(mask)

    def _propagate_impl(self, state, new_inputs: Dict[str, jax.Array]):
        D = self._dirty_cls
        vals = list(state["v"])
        carries = dict(state["c"])
        changed: List[Any] = [None] * len(self.nodes)   # DirtySets
        recomputed = jnp.int32(0)
        affected = jnp.int32(0)
        dirty_inputs = jnp.int32(0)
        rec_lvls: List[jax.Array] = []
        aff_lvls: List[jax.Array] = []

        for lvl, groups in zip(self.schedule, self._level_groups):
            ops = [i for i in lvl if self.nodes[i].kind != "input"]
            for idx in lvl:
                nd = self.nodes[idx]
                if nd.kind != "input":
                    continue
                old = vals[idx]
                if nd.name in new_inputs:
                    new = jnp.asarray(new_inputs[nd.name]).astype(old.dtype)
                    ch = D.from_diff(old, new, nd.block)
                    vals[idx] = new
                else:
                    ch = D.none(nd.num_blocks)
                changed[idx] = ch
                dirty_inputs += ch.count()
            if not ops:
                rec_lvls.append(jnp.int32(0))
                aff_lvls.append(jnp.int32(0))
                continue

            # Incoming dirty sets (cheap O(nb) mask pushing), then one
            # cond for the whole level: a clean level costs one compare.
            dirties = {i: graph_ops.edge_dirty(
                self.nodes[i], [changed[d] for d in self.nodes[i].deps],
                [vals[d] for d in self.nodes[i].deps])
                for i in ops}
            level_any = functools.reduce(
                jnp.logical_or, [dirties[i].any() for i in ops])

            lvl_groups = [g for g in groups
                          if self.nodes[g[0]].kind != "input"]

            def live(_, _ops=ops, _groups=lvl_groups, _dirties=dirties,
                     _vals=vals, _carries=carries):
                out_vals, out_changed, out_carries = {}, {}, {}
                rec = jnp.int32(0)
                for grp in _groups:
                    if len(grp) > 1 and self._group_batchable(grp, _vals):
                        news, chs = self._recompute_group(
                            grp, _vals, [_dirties[i] for i in grp])
                        for i, nv, ch in zip(grp, news, chs):
                            out_vals[i], out_changed[i] = nv, ch
                            rec += _dirties[i].count()
                        continue
                    for i in grp:
                        nd = self.nodes[i]
                        parents = [_vals[d] for d in nd.deps]
                        old_states = _carries.get(str(i))
                        nv, ch, st = self._recompute(
                            nd, parents, _vals[i], _dirties[i], old_states)
                        out_vals[i], out_changed[i] = nv, ch
                        if st is not None:
                            out_carries[str(i)] = st
                        rec += _dirties[i].count()
                aff = functools.reduce(
                    jnp.add, [out_changed[i].count() for i in _ops])
                return (tuple(out_vals[i] for i in _ops),
                        tuple(out_changed[i] for i in _ops),
                        tuple(out_carries[str(i)] for i in _ops
                              if _is_carry(self.nodes[i])),
                        rec, aff)

            def skip(_, _ops=ops, _vals=vals, _carries=carries):
                return (tuple(_vals[i] for i in _ops),
                        tuple(D.none(self.nodes[i].num_blocks)
                              for i in _ops),
                        tuple(_carries[str(i)] for i in _ops
                              if _is_carry(self.nodes[i])),
                        jnp.int32(0), jnp.int32(0))

            # Whole-level skip — but only where it pays.  XLA lowers a
            # cond by copying the taken branch's roots into the cond's
            # output buffers, so wrapping a level that carries big node
            # values costs O(value) memcpy per update even when live;
            # a big node's *sparse* path is already near-free when the
            # level is clean (k sentinel lanes, all dropped).  Tiny
            # levels — every reduce tree's upper tail, where a cutoff
            # kill strands the most dispatch — skip for one compare.
            tiny_level = all(self.nodes[i].num_blocks <= self.TINY_NB
                             for i in ops)
            if self.level_skip and tiny_level:
                lvl_vals, lvl_changed, lvl_carries, rec, aff = jax.lax.cond(
                    level_any, live, skip, None)
            else:
                lvl_vals, lvl_changed, lvl_carries, rec, aff = live(None)
            for i, nv, ch in zip(ops, lvl_vals, lvl_changed):
                vals[i] = nv
                changed[i] = ch
            carry_ops = [i for i in ops if _is_carry(self.nodes[i])]
            for i, st in zip(carry_ops, lvl_carries):
                carries[str(i)] = st
            recomputed += rec
            affected += aff
            rec_lvls.append(rec)
            aff_lvls.append(aff)

        stats = {"recomputed": recomputed, "affected": affected,
                 "dirty_inputs": dirty_inputs,
                 "rec_per_level": jnp.stack(rec_lvls),
                 "aff_per_level": jnp.stack(aff_lvls),
                 **self._boundary_stats(changed)}
        return {"v": tuple(vals), "c": carries}, stats

    # ------------------------------------------------------------------
    # Per-node recompute: regime pick + value cutoff
    # ------------------------------------------------------------------
    def _recompute(self, nd: GNode, parents, old, dirty, old_states=None,
                   regime: Optional[str] = None,
                   idx: Optional[jax.Array] = None):
        """Returns ``(new_value, changed_dirtyset, new_carry_or_None)``.

        ``regime`` forces the sparse/dense pick (the planned propagate —
        no in-graph cond, so no O(value) branch-result copies) and
        ``idx`` supplies host-extracted dirty lane indices for the sparse
        path; ``None`` keeps the legacy runtime ``lax.cond`` on the
        dirty count with in-graph ``nonzero``.
        """
        D = self._dirty_cls
        nb = nd.num_blocks

        if nd.kind == "escan":
            new = self._recompute_escan(nd, parents, old, dirty)
            return new, dirty.meet_diff(old, new, nd.block), None

        if _is_carry(nd):
            states = self._refold_states(nd, parents[0], old_states, dirty)
            k = self._ks[nd.idx]
            mask = dirty.to_mask()

            def sparse(_):
                new, ix, lc = graph_ops.causal_finalize_sparse(
                    nd, self.nodes, parents[0], states, old, mask, k,
                    idx=idx)
                return new, D.from_changed_lanes(ix, lc, nb)

            def dense(_):
                new = graph_ops.causal_finalize_dense(
                    nd, self.nodes, parents[0], states, old, mask)
                return new, dirty.meet_diff(old, new, nd.block)

            if regime is not None:
                new, ch = sparse(None) if regime == "sparse" else dense(None)
            else:
                new, ch = jax.lax.cond(
                    dirty.count() <= k, sparse, dense, None)
            return new, ch, states

        mask = dirty.to_mask()
        k = self._ks[nd.idx]

        # Tiny nodes (the upper levels of every reduce tree): the dense
        # masked pass is 4-5 XLA ops, the sparse regime 9-10 — on a
        # dispatch-bound propagate the regime machinery costs more than
        # recomputing all <= TINY_NB rows.
        if nb <= self.TINY_NB:
            new = graph_ops.dense_update(nd, self.nodes, parents, old, mask)
            return new, dirty.meet_diff(old, new, nd.block), None

        def sparse(_):
            new, ix, lc = graph_ops.sparse_update(
                nd, self.nodes, parents, old, mask, k, idx=idx)
            return new, D.from_changed_lanes(ix, lc, nb)

        def dense(_):
            new = self._dense(nd, parents, old, mask)
            return new, dirty.meet_diff(old, new, nd.block)

        if regime is not None:
            new, ch = sparse(None) if regime == "sparse" else dense(None)
        else:
            new, ch = jax.lax.cond(dirty.count() <= k, sparse, dense, None)
        return new, ch, None

    def _block_skip_ok(self, dtype) -> bool:
        if self.block_skip == "auto":
            return graph_ops.exact_dtype(dtype)
        return bool(self.block_skip)

    def _refold_states(self, nd: GNode, parent, old_states, dirty):
        """Carry states of a carry-causal node: block-skip reseed from the
        cache when bitwise-safe (Pallas tile-skip when routed), else the
        dense rescan oracle."""
        if not self._block_skip_ok(old_states.dtype):
            return graph_ops.causal_carry_states(nd, self.nodes, parent)
        if self.use_pallas:
            from repro.kernels.ops import dirty_causal_scan

            p = self.nodes[nd.deps[0]]
            xb = parent.reshape((p.num_blocks, p.block) + parent.shape[1:])
            contrib = jax.vmap(nd.lift)(xb)
            return dirty_causal_scan(
                contrib, old_states, dirty.start(), nd.op,
                identity=nd.identity, block=self.pallas_tile,
                interpret=self.interpret)
        return graph_ops.causal_carry_refold(
            nd, self.nodes, parent, old_states, dirty.start(), True)

    def _recompute_escan(self, nd: GNode, parents, old, dirty):
        """Carry pass: block-skip reseed from the cached carries when the
        dtype's arithmetic is exact (or forced), else the dense
        ``associative_scan`` oracle.  Pallas tile-skip when routed."""
        if not self._block_skip_ok(old.dtype):
            return graph_ops.dense_update(
                nd, self.nodes, parents, old, dirty.to_mask())
        if self.use_pallas:
            return self._pallas_escan(nd, parents[0], old, dirty)
        new = graph_ops.escan_block_skip(nd, parents[0], old, dirty.start())
        mask = dirty.to_mask()
        nb = nd.num_blocks
        new_b = new.reshape((nb, nd.block) + new.shape[1:])
        old_b = old.reshape((nb, nd.block) + old.shape[1:])
        sel = mask.reshape((nb,) + (1,) * (new_b.ndim - 1))
        return jnp.where(sel, new_b, old_b).reshape(old.shape)

    # ------------------------------------------------------------------
    # Level packing: batched sparse recompute of same-fn nodes
    # ------------------------------------------------------------------
    def _group_batchable(self, grp: List[int], vals) -> bool:
        """Static keys matched at compile; re-check the value shapes and
        dtypes now that they are known (trace time)."""
        ref = vals[grp[0]]
        if not all(vals[i].shape == ref.shape and vals[i].dtype == ref.dtype
                   for i in grp[1:]):
            return False
        pref = [vals[d] for d in self.nodes[grp[0]].deps]
        for i in grp[1:]:
            ps = [vals[d] for d in self.nodes[i].deps]
            if not all(a.shape == b.shape and a.dtype == b.dtype
                       for a, b in zip(pref, ps)):
                return False
        return True

    def _recompute_group(self, grp: List[int], vals, dirties):
        """One batched gather -> fn -> scatter for m same-fn nodes, under
        one regime cond for the whole group."""
        D = self._dirty_cls
        nd0 = self.nodes[grp[0]]
        nb = nd0.num_blocks
        masks = [d.to_mask() for d in dirties]
        count = functools.reduce(jnp.add, [d.count() for d in dirties])
        k = sum(self._ks[i] for i in grp)
        k = min(k, len(grp) * nb)

        def sparse(_):
            news, idxs, lcs = graph_ops.sparse_update_group(
                [self.nodes[i] for i in grp], self.nodes,
                [[vals[d] for d in self.nodes[i].deps] for i in grp],
                [vals[i] for i in grp], masks, k)
            chs = [D.from_changed_lanes(ix, lc, nb)
                   for ix, lc in zip(idxs, lcs)]
            return tuple(news), tuple(chs)

        def dense(_):
            news, chs = [], []
            for i, dirty, mask in zip(grp, dirties, masks):
                nd = self.nodes[i]
                parents = [vals[d] for d in nd.deps]
                new = self._dense(nd, parents, vals[i], mask)
                news.append(new)
                chs.append(dirty.meet_diff(vals[i], new, nd.block))
            return tuple(news), tuple(chs)

        news, chs = jax.lax.cond(count <= k, sparse, dense, None)
        return list(news), list(chs)

    # ------------------------------------------------------------------
    def _dense(self, nd: GNode, parents, old, dirty):
        if self.use_pallas and self._pallas_eligible(nd, parents, old):
            return self._pallas_dense(nd, parents, old, dirty)
        return graph_ops.dense_update(nd, self.nodes, parents, old, dirty)

    # ------------------------------------------------------------------
    # Pallas dirty-tile routing (elementwise / pair / stencil levels)
    # ------------------------------------------------------------------
    def _pallas_eligible(self, nd: GNode, parents, old) -> bool:
        if nd.kind not in ELEMENTWISE_KINDS + ("stencil",):
            return False
        if nd.kind == "reduce_level" and (
                self.nodes[nd.deps[0]].num_blocks != 2 * nd.num_blocks):
            return False                 # identity-padded odd level
        return True

    def _pallas_dense(self, nd: GNode, parents, old, dirty):
        from repro.kernels.ops import dirty_map

        nb = nd.num_blocks
        w_out = nd.block * _feat_size(old.shape)
        rows, shapes = [], []
        for d, val in zip(nd.deps, parents):
            p = self.nodes[d]
            # Mixed parent dtypes stay on the Pallas path (the old
            # eligibility check bailed to XLA): each input ref keeps its
            # ORIGINAL dtype — ``fn`` is traced into the kernel body on
            # exactly the dtypes the XLA dense path gives it, so any
            # promotion (or integer-exact work) happens inside ``fn``
            # identically, and the kernel's trailing astype covers the
            # output dtype.  Pre-casting here would silently change fns
            # that do dtype-sensitive work before promoting.
            if nd.kind == "reduce_level":
                bshape = (2,) + val.shape[1:]          # pair per out block
                rows.append(val.reshape(nb, int(math.prod(bshape))))
            elif nd.kind == "stencil":
                # Halo-aware: materialize each output block's
                # neighbourhood window as its row payload, so the tile
                # function stays local (the halo gather happens once,
                # outside the kernel).
                win = graph_ops._windows(nd, p, val)
                bshape = win.shape[1:]
                rows.append(win.reshape(nb, int(math.prod(bshape))))
            else:
                bshape = (p.block,) + val.shape[1:]
                rows.append(val.reshape(nb, int(math.prod(bshape))))
            shapes.append(bshape)

        def tile_fn(*tiles):
            t = tiles[0].shape[0]
            blocks = [x.reshape((t,) + s) for x, s in zip(tiles, shapes)]
            if nd.kind == "reduce_level":
                raw = nd.op(blocks[0][:, 0], blocks[0][:, 1])
            else:
                raw = jax.vmap(nd.fn)(*blocks)
            return raw.reshape(t, w_out)

        old_rows = old.reshape(nb, w_out)
        tile = self.pallas_tile
        pad = (-nb) % tile
        if pad:
            # Identity-pad the tail tile: padded lanes are never dirty,
            # so the tail tile only executes when its real rows are.
            rows = [jnp.concatenate(
                [r, jnp.zeros((pad, r.shape[1]), r.dtype)]) for r in rows]
            old_rows_p = jnp.concatenate(
                [old_rows, jnp.zeros((pad, w_out), old_rows.dtype)])
            dirty_p = jnp.concatenate([dirty, jnp.zeros((pad,), bool)])
        else:
            old_rows_p, dirty_p = old_rows, dirty

        out = dirty_map(tile_fn, rows, old_rows_p, dirty_p,
                        block=tile, interpret=self.interpret)
        if pad:
            out = out[:nb]
        # The kernel recomputes *whole* dirty tiles, including their clean
        # blocks.  By determinism those recompute to equal values — but
        # only modulo compiled-kernel-vs-XLA fusion differences (FMA can
        # shift a ulp).  Mask them back to `old` so clean blocks stay
        # bitwise stable and the changed-mask cutoff remains sound.
        out = jnp.where(dirty[:, None], out, old_rows)
        return out.reshape(old.shape)

    def _pallas_escan(self, nd: GNode, agg, old, dirty):
        """Carry pass through the block-skip Pallas kernel: clean tiles
        before the dirty suffix copy their cached carries without
        executing; the boundary tile reseeds from the cached prefix."""
        from repro.kernels.ops import dirty_causal_scan

        nb = nd.num_blocks
        ident = graph_ops._identity_row(nd, agg)[None]
        shifted = jnp.concatenate([ident, agg[:-1]], axis=0)
        out = dirty_causal_scan(
            shifted, old, dirty.start(), nd.op,
            identity=nd.identity, block=self.pallas_tile,
            interpret=self.interpret)
        mask = dirty.to_mask()
        sel = mask.reshape((nb,) + (1,) * (old.ndim - 1))
        return jnp.where(sel, out, old)
