"""Dirty-signature plan cache for the planned propagate.

The planned propagate (graph_compile.py) freezes, per update, a
per-node regime plan — skip / sparse / dense — from the mark pass's
dirty-count upper bounds, then runs a plan-specialized recompute
executable.  Freezing costs a host round-trip (read the counts, build
the plan, look up or compile the executable); under a sharded runtime
that sync would multiply per shard.  This module memoizes the whole
freeze behind a *dirty signature*:

  * the per-node dirty counts are **quantized** — 0 -> skip, counts
    above the sparse budget (or tiny nodes) -> dense, and sparse counts
    round up to the next power of two (the node's gather budget for
    this plan) — so every update maps to one of a small number of
    signatures rather than one per exact count;
  * the signature IS the plan: the cache maps it to a ``PlanEntry``
    holding a jitted recompute executable specialized to exactly that
    plan, with its sparse gather indices extracted **on device** from
    the mark masks (``graph_ops.mask_indices`` — running counts +
    ``searchsorted``, not the full sort ``jnp.nonzero`` lowers to nor a
    serializing scatter).  A signature hit therefore
    dispatches straight into the cached executable: the only host work
    is reading the quantized counts; the masks never leave the device
    and no plan is re-frozen — zero plan-freeze syncs in the serving
    steady state (repeated edit patterns).

The cache is an LRU bounded by ``cap``: every entry owns its *own*
``jax.jit`` wrapper, so evicting the entry really drops the compiled
executable (a shared jit cache keyed on a static plan argument would
keep every plan ever seen alive).  ``snapshot()`` feeds
``stats["plan_cache"]`` — hits / misses / evictions / size — which the
regression tests assert on: a repeated edit pattern must stop
re-freezing after its first update.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Tuple

__all__ = ["PlanEntry", "PlanCache", "next_pow2",
           "plan_to_json", "plan_from_json"]


def next_pow2(c: int) -> int:
    """Smallest power of two >= c (c >= 1)."""
    return 1 << (int(c) - 1).bit_length()


def plan_to_json(plan: Tuple[Any, ...]) -> list:
    """A plan signature as JSON-safe data: regime strings pass through,
    ``("sparse", k)`` becomes ``["sparse", k]``.  Used by session
    checkpoints to persist which signatures a session had warmed."""
    return [list(p) if isinstance(p, tuple) else p for p in plan]


def plan_from_json(sig: list) -> Tuple[Any, ...]:
    """Inverse of ``plan_to_json`` — back to the hashable cache key."""
    return tuple(tuple(p) if isinstance(p, list) else p for p in sig)


@dataclasses.dataclass
class PlanEntry:
    """One frozen plan: the signature it serves and its executable."""

    plan: Tuple[Any, ...]            # per-node regimes (the signature)
    fn: Callable                     # jitted plan-specialized propagate


class PlanCache:
    """Bounded LRU of frozen plans, keyed by dirty signature.

    ``on_event``, when given, is called with ``"hit"`` / ``"miss"`` /
    ``"evict"`` as they happen — the observability layer's bridge into
    a metric registry without the cache knowing about metrics.
    """

    def __init__(self, cap: int = 64,
                 on_event: Callable[[str], None] = None):
        assert cap >= 1, cap
        self.cap = int(cap)
        self._entries: "OrderedDict[Any, PlanEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.on_event = on_event

    def _fire(self, kind: str) -> None:
        if self.on_event is not None:
            self.on_event(kind)

    def lookup(self, sig) -> Any:
        """The entry for ``sig`` (refreshing its LRU slot), or None."""
        entry = self._entries.get(sig)
        if entry is None:
            return None
        self._entries.move_to_end(sig)
        self.hits += 1
        self._fire("hit")
        return entry

    def insert(self, sig, entry: PlanEntry) -> PlanEntry:
        """Record a freshly frozen plan; evicts the LRU entry past cap."""
        self.misses += 1
        self._fire("miss")
        self._entries[sig] = entry
        self._entries.move_to_end(sig)
        while len(self._entries) > self.cap:
            self._entries.popitem(last=False)
            self.evictions += 1
            self._fire("evict")
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def snapshot(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._entries),
                "cap": self.cap}
