"""Static SP-dag tracer for block-granular self-adjusting programs.

The host engine (``repro.core.engine``) builds its RSP tree *dynamically*:
every run records reads, scopes, and series/parallel composition as Python
closures execute.  None of that jits.  This module is the hardware path's
answer: a **tracing API** that records, once, the static SP-dag of a
block-tensor computation, which ``graph_compile`` then level-schedules and
compiles into a single jitted ``propagate``.

A traced program is a dag of block-granular ops.  Each node produces a
tensor whose leading axis is ``num_blocks * block`` (a ``BlockTensor``
worth of modifiables); each edge carries a *reader index map* — which
blocks of the input does block ``i`` of the output read:

  ============  =========================================  ================
  op            reader index map (out block i reads)       dirty transfer
  ============  =========================================  ================
  map           in block i                                 identity
  zip_map       block i of both inputs                     union
  reduce_level  in blocks 2i, 2i+1                         pairwise OR
  stencil(r)    in blocks i-r .. i+r (clamped)             dilation by r
  scan carry    in blocks 0 .. i-1                         prefix OR
  gather(A)     block i + A data-dependent neighbours      identity OR
                (indices from block i's own contents)      mask[idx].any
  ============  =========================================  ================

This is the static special case the paper itself singles out ("the RSP
tree will always look the same", Section 2): because the dag never
changes shape, the reader sets of the host engine collapse into these
index maps and change propagation becomes mask pushing + masked
recompute (see graph_compile.py).

``seq``/``par`` mirror the host engine's S/P composition: ``par`` asserts
branches are independent (they may share a schedule level), ``seq``
imposes S-node ordering (later branches are scheduled strictly after
earlier ones, even without a data edge).

Typical use::

    g = GraphBuilder()
    x = g.input("x", n=4096, block=16)
    y = g.map(lambda b: b * 2.0 + 1.0, x)
    s = g.stencil(lambda w: w[16:32] + 0.5 * (w[:16] + w[32:]), y, radius=1)
    total = g.reduce_tree(jnp.add, s, identity=0.0)
    cg = g.compile(max_sparse=64)
    state = cg.init(x=data)
    state, stats = cg.propagate(state, {"x": new_data})
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

__all__ = ["GraphBuilder", "Handle", "GNode", "level_schedule"]

ELEMENTWISE_KINDS = ("map", "zip_map", "reduce_level")
KINDS = ("input",) + ELEMENTWISE_KINDS + ("stencil", "escan", "causal",
                                          "gather")


@dataclasses.dataclass
class GNode:
    """One block-granular op in the traced SP-dag (static metadata only)."""

    idx: int
    kind: str                       # one of KINDS
    num_blocks: int                 # output block count
    block: int                      # elements per output block
    deps: Tuple[int, ...]           # data-edge predecessors (node idxs)
    control: Tuple[int, ...] = ()   # S-composition predecessors (node idxs)
    fn: Optional[Callable] = None   # per-block function (map/zip_map/stencil)
    op: Optional[Callable] = None   # combining op (reduce_level/escan/carry)
    identity: Any = None            # identity of ``op`` (fill / scan seed)
    radius: int = 0                 # stencil radius (blocks)
    fill: Any = None                # stencil boundary fill (None = clamp)
    lift: Optional[Callable] = None      # carry-causal: block -> state
    finalize: Optional[Callable] = None  # carry-causal: (state, block) -> out
    idx_fn: Optional[Callable] = None    # gather: blocked parent -> [nb, A]
    arity: int = 0                       # gather: neighbour count per lane
    packed_fn: Optional[Callable] = None  # gather: (own, nbrs) -> out block
    region: Optional[str] = None         # hybrid-runtime region tag
    name: str = ""

    @property
    def n(self) -> int:
        return self.num_blocks * self.block


@dataclasses.dataclass(frozen=True)
class Handle:
    """Reference to a traced node, returned by every GraphBuilder op."""

    builder: "GraphBuilder" = dataclasses.field(repr=False)
    idx: int = 0

    @property
    def node(self) -> GNode:
        return self.builder.nodes[self.idx]

    @property
    def num_blocks(self) -> int:
        return self.node.num_blocks

    @property
    def block(self) -> int:
        return self.node.block


def level_schedule(nodes: Sequence[GNode]):
    """Group nodes into levels by longest path from an input, over data
    edges plus S-composition control edges.  Nodes within a level are
    independent by SP structure — the paper's guarantee that change
    propagation may proceed in parallel under P nodes.  Shared by both
    backends (graph_compile jit-fuses a level; the host backend runs it
    under ``parallel_for``), so their schedules cannot drift.

    Returns ``(level_of, schedule)``: node idx -> level, and the list of
    node-idx buckets per level.
    """
    level = {}
    for nd in nodes:
        preds = tuple(nd.deps) + tuple(nd.control)
        level[nd.idx] = (0 if nd.kind == "input"
                         else 1 + max(level[p] for p in preds))
    num_levels = max(level.values()) + 1 if level else 0
    schedule: List[List[int]] = [[] for _ in range(num_levels)]
    for nd in nodes:
        schedule[level[nd.idx]].append(nd.idx)
    return level, schedule


class GraphBuilder:
    """Records a static SP-dag of block-granular ops."""

    def __init__(self):
        self.nodes: List[GNode] = []
        self.inputs: dict = {}          # name -> node idx
        self.outputs: List[int] = []    # explicitly marked outputs
        # S-composition context: node idxs the *next* traced op must be
        # scheduled after (set while inside the later branches of seq()).
        self._control: Tuple[int, ...] = ()
        # Region stack for the context-manager form of S/P composition
        # (seq_region / par_region, used by the repro.sac frontend).
        self._regions: List[Any] = []
        # Hybrid-runtime region tags (static_region): ops traced while a
        # tag is active carry it; the hybrid backend compiles each
        # maximal same-tag run as one CompiledGraph fragment.
        self._region_tags: List[str] = []

    # ------------------------------------------------------------------
    def _add(self, kind: str, num_blocks: int, block: int,
             deps: Sequence[int], **kw) -> Handle:
        control = self._control
        if self._regions:
            extra = self._regions[-1].control()
            control = control + tuple(i for i in extra if i not in control)
        node = GNode(idx=len(self.nodes), kind=kind, num_blocks=num_blocks,
                     block=block, deps=tuple(deps), control=control,
                     region=self._region_tags[-1] if self._region_tags
                     else None, **kw)
        self.nodes.append(node)
        if self._regions:
            self._regions[-1].note(node.idx)
        return Handle(self, node.idx)

    @staticmethod
    def _blocks(n: int, block: int) -> int:
        assert n % block == 0, f"size {n} not divisible by block {block}"
        return n // block

    # ------------------------------------------------------------------
    # Sources
    # ------------------------------------------------------------------
    def input(self, name: str, n: int, block: int = 1) -> Handle:
        """Declare a block-modifiable input of ``n`` leading elements."""
        assert name not in self.inputs, f"duplicate input {name!r}"
        h = self._add("input", self._blocks(n, block), block, (), name=name)
        self.inputs[name] = h.idx
        return h

    # ------------------------------------------------------------------
    # Block ops
    # ------------------------------------------------------------------
    def map(self, f: Callable, x: Handle, out_block: Optional[int] = None,
            name: str = "") -> Handle:
        """Apply ``f`` to each block independently.

        ``f`` maps one block ``[block, *feat] -> [out_block, *out_feat]``
        (or ``-> [*out_feat]`` when ``out_block == 1``, e.g. a block-local
        aggregation).  Identity reader map: out block i reads in block i.
        """
        ob = x.block if out_block is None else out_block
        return self._add("map", x.num_blocks, ob, (x.idx,), fn=f,
                         name=name or "map")

    def zip_map(self, f: Callable, x: Handle, y: Handle,
                out_block: Optional[int] = None, name: str = "") -> Handle:
        """Apply ``f`` to aligned block pairs of two inputs.

        Inputs must agree on ``num_blocks`` (block sizes may differ, e.g.
        zipping data blocks with per-block carries).
        """
        assert x.num_blocks == y.num_blocks, (x.num_blocks, y.num_blocks)
        ob = x.block if out_block is None else out_block
        return self._add("zip_map", x.num_blocks, ob, (x.idx, y.idx), fn=f,
                         name=name or "zip_map")

    def reduce_tree(self, op: Callable, x: Handle, identity: Any = 0.0,
                    name: str = "") -> Handle:
        """Balanced-tree reduction over all blocks (paper Algorithm 1).

        Expands into one block-local fold plus ceil(log2(num_blocks))
        pairwise ``reduce_level`` nodes; a k-block edit dirties
        O(k log(n/k)) of them (Theorem 4.2), and the value-equality
        cutoff at every level can stop propagation earlier still.

        Any block count works: an odd level is conceptually padded with
        one ``identity`` block (the padding never materializes in state —
        each level's forward/sparse recompute supplies the identity for
        the missing right child).
        """
        name = name or "reduce"
        cur = x
        if x.block > 1:
            from .reduce import _fold  # balanced in-block fold

            cur = self.map(
                lambda b, _op=op, _id=identity: _fold(_op, _id, b[None], 1)[0],
                x, out_block=1, name=f"{name}.leaf")
        while cur.num_blocks > 1:
            cur = self._add("reduce_level", (cur.num_blocks + 1) // 2, 1,
                            (cur.idx,), op=op, identity=identity,
                            name=f"{name}.lvl")
        return cur

    def stencil(self, f: Callable, x: Handle, radius: int = 1,
                fill: Any = None, name: str = "") -> Handle:
        """Sliding-window block op: out block i reads blocks i-r .. i+r.

        ``f`` maps the concatenated window ``[(2r+1)*block, *feat]`` to one
        output block ``[block, *feat']``.  Out-of-range neighbours clamp to
        the edge block, or are filled with ``fill`` when given.  Dirty
        transfer is mask dilation by ``radius``.
        """
        assert radius >= 1
        return self._add("stencil", x.num_blocks, x.block, (x.idx,), fn=f,
                         radius=radius, fill=fill, name=name or "stencil")

    def causal(self, f: Optional[Callable], x: Handle,
               out_block: Optional[int] = None, name: str = "", *,
               lift: Optional[Callable] = None,
               op: Optional[Callable] = None,
               finalize: Optional[Callable] = None,
               identity: Any = 0.0) -> Handle:
        """Causal op: out block i reads parent blocks 0 .. i (inclusive).

        This is the interval-carrying edge kind: its dirty transfer is
        the *suffix hull* — an edit at block j dirties [j, nb), which the
        interval ``DirtySet`` represents exactly in O(1) space.  It is
        the graph-runtime form of causal attention: per output block the
        reader set is the whole prefix.

        ``f(x, i)`` receives the FULL parent array ``[n, *feat]`` plus
        the (traced) output block index ``i`` and must restrict itself to
        rows ``< (i+1) * block`` (e.g. via a causal mask computed from
        ``i``) — the runtime relies on that contract for incremental
        soundness and may zero-fill rows beyond the prefix.

        **Carry form** (``lift``/``op``/``finalize`` given, ``f`` may be
        None): the prefix dependence is declared as a monoid —

            out block i = finalize(states[i], block_i),
            states[i]   = fold(op, lift(block_0) .. lift(block_i))

        with ``op`` associative and ``identity`` its identity.  The
        runtime then caches the per-block carry ``states`` in the
        propagation state: a dirty suffix recombines the cached prefix
        state in O(suffix) work instead of rescanning the full prefix per
        block (the flash-style block-skip; the running-softmax state of
        streaming attention is exactly such a monoid).  Propagation cost
        drops from O(suffix * n) to O(n) dense work, and on the Pallas
        path clean tiles are skipped entirely
        (``repro.kernels.dirty_causal``).
        """
        ob = x.block if out_block is None else out_block
        if lift is not None or op is not None or finalize is not None:
            assert lift is not None and op is not None \
                and finalize is not None, (
                    "carry-causal needs all of lift/op/finalize")
            return self._add("causal", x.num_blocks, ob, (x.idx,), fn=f,
                             lift=lift, op=op, finalize=finalize,
                             identity=identity, name=name or "causal")
        assert f is not None, "causal needs f(x, i) or a carry spec"
        return self._add("causal", x.num_blocks, ob, (x.idx,), fn=f,
                         name=name or "causal")

    def gather(self, fn: Optional[Callable], idx_fn: Callable, x: Handle,
               arity: int = 1, out_block: Optional[int] = None,
               name: str = "", packed: Optional[Callable] = None) -> Handle:
        """Data-dependent reader sets with statically-bounded arity.

        The dynamic-dependency edge kind: out block i reads block i of the
        parent plus up to ``arity`` *data-dependent* neighbour blocks —
        the static-reader-map relaxation that covers the paper's
        tree-contraction / BST workloads (a node reads its parent's and
        children's state, and who those are is itself data).

          * ``idx_fn(xb)`` maps the blocked parent ``[nb, block, *feat]``
            to int32 neighbour indices ``[nb, arity]``.  Row i may depend
            ONLY on block i (so an index change always makes lane i dirty
            through the implicit identity edge), and out-of-range slots
            should be clamped to i (self-reads are free).
          * ``fn(x_full, i)`` receives the full parent array plus the
            (traced) output block index and must restrict its *value*
            dependence to blocks ``{i} | set(idx_fn(xb)[i])`` — the
            runtime relies on that contract for incremental soundness
            (guard every neighbour use with the predicate that selected
            the neighbour).

        Dirty transfer is the identity map unioned with the reverse
        neighbour map evaluated on cached values: out i is dirty iff
        block i changed or any block in ``idx[i]`` changed.  Evaluating
        on pre-edit values is sound because a lane whose indices changed
        is dirty through the identity component.

        **Packed form** (``packed`` given; ``fn`` may be None):
        ``packed(own, nbrs)`` receives the lane's own block
        ``[block, *feat]`` plus exactly its declared neighbour blocks
        ``[arity, block, *feat]`` in ``idx_fn`` row order (clamped
        in-range).  The sparse recompute then gathers only the
        ``k * (1 + arity)`` blocks the dirty lanes actually read instead
        of reassembling the full parent per lane — same dirty transfer,
        same recomputed-block counts.  The packed contract tightens
        ``idx_fn``: it must be row-wise *position-independent* (the
        runtime evaluates it on gathered row subsets, so an ``idx_fn``
        reading ``arange`` positions would see subset positions).
        """
        assert arity >= 1
        assert fn is not None or packed is not None, (
            "gather needs fn(x_full, i) or a packed(own, nbrs) form")
        ob = x.block if out_block is None else out_block
        return self._add("gather", x.num_blocks, ob, (x.idx,), fn=fn,
                         idx_fn=idx_fn, arity=int(arity), packed_fn=packed,
                         name=name or "gather")

    def scan(self, op: Callable, x: Handle, identity: Any = 0.0,
             name: str = "") -> Handle:
        """Inclusive prefix scan of an associative ``op`` over the leading
        axis, traced as the classic three-node pipeline: block aggregates
        (map) -> exclusive carry scan over aggregates -> block-local scans
        seeded by the carries (zip_map).  A k-block edit recomputes the k
        local aggregates, the (cheap, nb-element) carry pass, and only the
        downstream blocks whose carry *value* actually changed.
        """
        name = name or "scan"
        from .reduce import _fold

        agg = self.map(
            lambda b, _op=op, _id=identity: _fold(_op, _id, b[None], 1)[0],
            x, out_block=1, name=f"{name}.agg")
        carry = self._add("escan", x.num_blocks, 1, (agg.idx,), op=op,
                          identity=identity, name=f"{name}.carry")

        def local(bx, cb, _op=op):
            import jax

            scanned = jax.lax.associative_scan(_op, bx, axis=0)
            return _op(cb, scanned)    # cb [1,*f] broadcasts over the block

        return self.zip_map(local, x, carry, name=f"{name}.local")

    # ------------------------------------------------------------------
    # SP composition (mirrors Engine.seq-by-default / Engine.par)
    # ------------------------------------------------------------------
    def par(self, *thunks: Callable[[], Any]) -> List[Any]:
        """P-node: trace branches as independent (level-sharable)."""
        return [t() for t in thunks]

    def seq(self, *thunks: Callable[[], Any]) -> List[Any]:
        """S-node: trace branches in series.  Ops of branch i+1 are
        scheduled strictly after every op of branch i, even when no data
        edge connects them (control edges in the level scheduler)."""
        saved = self._control
        out = []
        prev: Tuple[int, ...] = ()
        for t in thunks:
            first = len(self.nodes)
            self._control = saved + prev
            out.append(t())
            created = tuple(range(first, len(self.nodes)))
            if created:        # a branch tracing nothing keeps the chain
                prev = created
        self._control = saved
        return out

    @contextlib.contextmanager
    def seq_region(self):
        """Context-manager S-composition: every op traced inside is
        scheduled strictly after the op (or nested region) traced just
        before it, even without a data edge.  The statement-level form of
        ``seq`` used by the ``repro.sac`` frontend."""
        base = self._regions[-1].control() if self._regions else ()
        region = _SeqRegion(base)
        self._regions.append(region)
        try:
            yield
        finally:
            self._regions.pop()
            if self._regions:
                self._regions[-1].absorb(region.created)

    @contextlib.contextmanager
    def par_region(self):
        """Context-manager P-composition: ops traced inside are mutually
        independent (they suspend the innermost seq chaining); on exit
        they collectively form one step of the enclosing region."""
        base = self._regions[-1].control() if self._regions else ()
        region = _ParRegion(base)
        self._regions.append(region)
        try:
            yield
        finally:
            self._regions.pop()
            if self._regions:
                self._regions[-1].absorb(region.created)

    @contextlib.contextmanager
    def static_region(self, tag: str):
        """Tag every op traced inside as belonging to hybrid-runtime
        region ``tag``.  The graph and host backends ignore tags; the
        hybrid backend (``repro.sac.hybrid``) compiles each maximal
        same-tag run of the dag as one ``CompiledGraph`` fragment and
        keeps the cross-region boundary as host-orchestrated dirty
        transfer.  Nesting replaces the tag for the inner extent."""
        self._region_tags.append(str(tag))
        try:
            yield
        finally:
            self._region_tags.pop()

    def output(self, *handles: Handle) -> None:
        """Mark result nodes (defaults to dag sinks when never called)."""
        for h in handles:
            self.outputs.append(h.idx)

    # ------------------------------------------------------------------
    def sinks(self) -> List[int]:
        used = set()
        for nd in self.nodes:
            used.update(nd.deps)
        return [nd.idx for nd in self.nodes if nd.idx not in used]

    def compile(self, max_sparse="auto", use_pallas="auto",
                interpret: Optional[bool] = None, pallas_tile: int = 8,
                dirty: str = "mask", donate: bool = True,
                block_skip="auto", level_skip: bool = True,
                plan: bool = True, mesh=None, plan_cache: int = 64):
        """Level-schedule the dag and build the jitted runtime.

        ``max_sparse="auto"`` calibrates the sparse/dense crossover per
        level from a timed warmup pass (see autotune.py); pass an int for
        the old constant behaviour.  ``dirty`` picks the DirtySet
        representation: ``"mask"`` (exact per-block) or ``"interval"``
        (suffix/interval hull — O(1) space, exact for causal programs).

        ``donate=True`` (default) donates the state to the jitted
        propagate, so untouched node values alias through and sparse
        recomputes scatter in place instead of copying every node's
        buffer; a state read (``value``/``result``) becomes invalid once
        that state is passed to a later ``propagate`` — copy first if you
        need it across updates.  ``donate=False`` restores the old
        copying behaviour.

        ``block_skip`` routes escan / carry-causal recomputes through the
        block-skip path that reseeds from cached carry state:
        ``"auto"`` enables it only for exactly-associative dtypes (ints /
        bools — bitwise-safe re-bracketing), ``True`` forces it (floats
        re-associate at ulp level), ``False`` keeps the dense rescan.

        ``plan=True`` (default) splits propagation into the paper's mark
        and recompute phases: a tiny jitted mark pass over-approximates
        every node's dirty count (no value cutoff), the host freezes a
        per-node skip/sparse/dense plan from it, and a plan-specialized
        recompute executable runs with no in-graph branching — clean
        nodes simply do not appear in it.  One executable is compiled
        and cached per distinct plan.  ``plan=False`` keeps the single
        executable with runtime ``lax.cond`` regime picks.

        ``level_skip=True`` additionally wraps all-tiny schedule levels
        of the plan=False executable in one ``lax.cond`` on their
        aggregate dirty count (clean level = one scalar compare).

        ``mesh`` (a one-axis ``jax.sharding.Mesh``, or an int shard
        count resolved via ``repro.shardlib.block_mesh``) shards the
        block axis of every node whose block count divides the mesh
        size over the mesh devices; propagation then runs as one
        ``shard_map`` program with per-shard dirty masks and
        collectives only at level barriers (see DESIGN.md §Sharded
        propagation).  Outputs and stats stay bitwise identical to the
        single-device runtime.  ``plan_cache`` bounds the planned
        mode's dirty-signature LRU (distinct frozen plans kept live).
        """
        from .graph_compile import CompiledGraph

        return CompiledGraph(self, max_sparse=max_sparse,
                             use_pallas=use_pallas, interpret=interpret,
                             pallas_tile=pallas_tile, dirty=dirty,
                             donate=donate, block_skip=block_skip,
                             level_skip=level_skip, plan=plan, mesh=mesh,
                             plan_cache=plan_cache)


class _SeqRegion:
    """Statement-level S chaining: each op is ordered after the previous."""

    __slots__ = ("prev", "created")

    def __init__(self, base: Tuple[int, ...] = ()):
        self.prev: Tuple[int, ...] = base
        self.created: List[int] = []

    def control(self) -> Tuple[int, ...]:
        return self.prev

    def note(self, idx: int) -> None:
        self.prev = (idx,)
        self.created.append(idx)

    def absorb(self, nodes: List[int]) -> None:
        if nodes:
            self.prev = tuple(nodes)
            self.created.extend(nodes)


class _ParRegion:
    """Branches share the control captured at entry; mutually unordered."""

    __slots__ = ("base", "created")

    def __init__(self, base: Tuple[int, ...]):
        self.base = base
        self.created: List[int] = []

    def control(self) -> Tuple[int, ...]:
        return self.base

    def note(self, idx: int) -> None:
        self.created.append(idx)

    def absorb(self, nodes: List[int]) -> None:
        self.created.extend(nodes)
