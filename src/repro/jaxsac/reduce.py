"""Incremental balanced reductions — the paper's Algorithm 1 on TPU.

The divide-and-conquer sum of the paper keeps one modifiable per internal
node of a balanced binary tree; updating k of n leaves re-executes
O(k log(1 + n/k)) readers (Theorem 4.2).

``IncrementalReduce`` is now a thin wrapper over the ``repro.sac``
tracing frontend: the reduction is *traced* (``@sac.incremental`` over
``sac.reduce``) into one block-local fold plus ceil(log2(num_blocks))
pairwise combine levels, and the compiled ``propagate`` supplies
everything this module once hand-rolled — upward dirty-mask pushing, the
Algorithm-2 value-equality cutoff per level, and the sparse-gather vs
dense-masked regime switch (crossover auto-tuned per level unless
``max_sparse`` is given).  Any block count works: odd tree levels pad
with the op identity.  The hand-built implementation is kept verbatim
below as ``_LegacyIncrementalReduce`` (it is the bitwise-equivalence
oracle in tests/test_graph.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .core import BlockTensor, dirty_from_diff, broadcast_mask as _bc

__all__ = ["IncrementalReduce"]


@dataclasses.dataclass(frozen=True)
class IncrementalReduce:
    """Self-adjusting reduction of ``op`` over n elements in blocks.

    ``op`` must be associative with ``identity``; the element arrays may
    have trailing feature dims (reduced only over the leading axis).
    Traced through ``@sac.incremental`` and backed by the compiled
    SP-dag runtime: ``init`` runs the initial pass, ``update`` is the
    jitted change propagation.  ``max_sparse="auto"`` (default)
    calibrates the sparse/dense crossover per level at compile time.
    """

    n: int
    block: int = 1
    op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add
    identity: float = 0.0
    max_sparse: Any = "auto"      # sparse-path budget per level
    use_pallas: Any = False       # route dense levels through dirty_map

    def __post_init__(self):
        assert self.n % self.block == 0
        from repro import sac

        prog = sac.incremental(
            lambda x: sac.reduce(self.op, x, identity=self.identity),
            block=self.block)
        handle = prog.compile(x=self.n, max_sparse=self.max_sparse,
                              use_pallas=self.use_pallas)
        object.__setattr__(self, "_cg", handle.cg)

    @property
    def num_blocks(self) -> int:
        return self.n // self.block

    @property
    def num_levels(self) -> int:
        return max(int(math.ceil(math.log2(self.num_blocks))), 0)

    def init(self, data: jax.Array) -> Dict[str, Any]:
        """The initial run: build every level of the aggregation tree."""
        assert data.shape[0] == self.n
        return self._cg.init(x=data)

    def result(self, state: Dict[str, Any]) -> jax.Array:
        return self._cg.result(state)[0]

    def update(self, state: Dict[str, Any], new_data: jax.Array,
              ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        """Change propagation for a replacement of the leaf array.

        Returns (new_state, stats); stats['recomputed'] counts recomputed
        tree nodes (the realized computation distance W_delta) and
        stats['affected'] counts value-changed nodes.
        """
        state, stats = self._cg.propagate(state, {"x": new_data})
        return state, {"recomputed": stats["recomputed"],
                       "affected": stats["affected"]}


# ---------------------------------------------------------------------------
# The pre-graph hand-rolled implementation (reference oracle).
#
# Two propagation regimes, chosen at runtime by dirty count (this is the
# TPU translation of the paper's observation that from-scratch wins past a
# crossover update size):
#
#   * sparse — gather the <= max_sparse dirty parents, recompute just
#     those lanes, scatter back: O(k) work per level, O(k log n) total.
#   * dense  — recompute every parent on the level under a mask: O(n)
#     work but one fused pass, better for large k.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _LegacyIncrementalReduce:
    """Hand-built dirty-mask bookkeeping (kept as equivalence oracle)."""

    n: int
    block: int = 1
    op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add
    identity: float = 0.0
    max_sparse: int = 64          # sparse-path budget per level

    def __post_init__(self):
        assert self.n % self.block == 0
        nb = self.n // self.block
        assert nb & (nb - 1) == 0, "block count must be a power of two"

    @property
    def num_blocks(self) -> int:
        return self.n // self.block

    @property
    def num_levels(self) -> int:
        return int(math.log2(self.num_blocks))

    # ------------------------------------------------------------------
    def _leaf_agg(self, data: jax.Array) -> jax.Array:
        nb = self.num_blocks
        blocks = data.reshape((nb, self.block) + data.shape[1:])
        return _fold(self.op, self.identity, blocks, axis=1)

    def init(self, data: jax.Array) -> Dict[str, Any]:
        """The initial run: build every level of the aggregation tree."""
        assert data.shape[0] == self.n
        leaves = BlockTensor.clean(data, self.block)
        level = self._leaf_agg(data)
        levels: List[jax.Array] = [level]
        for _ in range(self.num_levels):
            level = self.op(level[0::2], level[1::2])
            levels.append(level)
        return {"leaves": leaves, "levels": levels}

    def result(self, state: Dict[str, Any]) -> jax.Array:
        return state["levels"][-1][0]

    # ------------------------------------------------------------------
    def update(self, state: Dict[str, Any], new_data: jax.Array,
              ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        """Change propagation for a replacement of the leaf array.

        Returns (new_state, stats); stats['recomputed'] counts recomputed
        tree nodes (the realized computation distance W_delta) and
        stats['affected'] counts value-changed nodes.
        """
        leaves: BlockTensor = state["leaves"].write(new_data)
        dirty = leaves.dirty
        levels = list(state["levels"])

        # Level 0: recompute leaf aggregates of dirty blocks.
        new0 = self._leaf_agg(leaves.data)
        lvl0 = jnp.where(_bc(dirty, levels[0]), new0, levels[0])
        recomputed = jnp.sum(dirty.astype(jnp.int32))
        # value cutoff: a block whose aggregate didn't change is clean.
        changed = dirty & dirty_from_diff(levels[0], lvl0, 1)
        levels[0] = lvl0
        affected = jnp.sum(changed.astype(jnp.int32))

        for l in range(self.num_levels):
            parent_dirty = changed[0::2] | changed[1::2]
            old_parent = levels[l + 1]
            kids = levels[l]
            n_par = old_parent.shape[0]

            def dense(_):
                new_parent = self.op(kids[0::2], kids[1::2])
                return jnp.where(_bc(parent_dirty, old_parent),
                                 new_parent, old_parent)

            def sparse(_):
                k = min(self.max_sparse, n_par)
                (idx,) = jnp.nonzero(parent_dirty, size=k, fill_value=n_par)
                l_kid = kids.at[2 * idx].get(mode="fill",
                                             fill_value=self.identity)
                r_kid = kids.at[2 * idx + 1].get(mode="fill",
                                                 fill_value=self.identity)
                vals = self.op(l_kid, r_kid)
                return old_parent.at[idx].set(vals, mode="drop")

            count = jnp.sum(parent_dirty.astype(jnp.int32))
            use_sparse = count <= min(self.max_sparse, n_par)
            new_level = jax.lax.cond(use_sparse, sparse, dense, None)
            recomputed = recomputed + count
            changed = parent_dirty & dirty_from_diff(old_parent, new_level, 1)
            affected = affected + jnp.sum(changed.astype(jnp.int32))
            levels[l + 1] = new_level

        return ({"leaves": leaves.clear(), "levels": levels},
                {"recomputed": recomputed, "affected": affected})


def _fold(op, identity, blocks: jax.Array, axis: int) -> jax.Array:
    """Balanced reduce over ``axis`` with ``op`` (keeps op generic;
    ``identity`` may be a scalar or a per-element [*feat] array)."""
    out = jnp.moveaxis(blocks, axis, 1)
    while out.shape[1] > 1:
        if out.shape[1] % 2:
            pad = jnp.broadcast_to(jnp.asarray(identity, out.dtype),
                                   out[:, :1].shape)
            out = jnp.concatenate([out, pad], axis=1)
        out = op(out[:, 0::2], out[:, 1::2])
    return out[:, 0]
