"""Mesh-sharded change propagation: the block axis over devices.

``CompiledGraph(mesh=...)`` partitions every node's block axis into
contiguous per-device chunks and runs the planned recompute as ONE
``shard_map`` program (``ShardedPropagator.planned_fn``).  The layout
rule is per node:

  * **sharded** — ``num_blocks % S == 0`` (S = mesh size): the value
    (and a carry node's cached states) live as ``[num_blocks/S]``-block
    chunks, one per device, and recompute work is local to each shard;
  * **replicated** — everything else (a reduce tree's upper levels once
    a level's blocks drop below the shard count, odd levels a prime
    block count produces, and — soundness, not shape — ``escan`` /
    carry-``causal`` nodes whose dtype is not exactly associative, see
    below): every device holds the full value and recomputes it
    identically, which is bitwise-trivially equal to single-device.

Cross-shard communication is confined to level barriers, one collective
pattern per edge kind:

  * a replicated node reading a sharded parent **all-gathers** it and
    combines locally (the reduce-tree tail switches to
    all-gather-then-local-combine exactly when a level stops dividing);
  * ``stencil`` exchanges ``radius`` **edge blocks per neighbour**
    (``ppermute`` halos; global edges keep their clamp/fill semantics);
  * ``escan`` / carry-``causal`` exchange **one carry state per shard
    boundary per level**: each shard scans its own chunk with the
    cached-carry block-skip recombination, shard totals are
    all-gathered and folded into a per-shard prefix (the Ladner-Fischer
    step across shards), and one ``op`` application seeds each chunk.
    The cross-shard fold re-brackets the monoid, so this path is gated
    to exactly-associative dtypes (ints/bools) — the same
    ``block_skip`` soundness rule the single-device runtime applies —
    and float scans stay replicated, keeping every output bitwise
    identical to the single-device runtime;
  * dirty *masks* are pushed on their full (replicated) form — they are
    ``num_blocks`` bools, a per-level all-gather of each recomputed
    node's changed chunk — so the transfer algebra (dirtyset.py) is
    byte-for-byte the single-device one and ``affected`` /
    ``recomputed`` counts cannot drift.

Sparse recomputes stay per-shard: each device extracts its local dirty
lane indices from its mask chunk (``graph_ops.mask_indices``) and
gathers/scatters only its own blocks, so a plan-cache hit dispatches
the whole sharded update with no host round-trip at all.
``stats["recomputed_per_shard"]`` reports each shard's local masked
work ([S] vector; replicated nodes charge their full count to every
shard, so its sum can exceed ``recomputed`` when a program has
replicated tails).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import shardlib
from repro.jaxsac.core import broadcast_mask as _bc
from repro.jaxsac.core import dirty_from_diff

from . import graph_ops
from .graph_ops import _identity_row, _lane_changed, _windows, mask_indices

try:                                     # jax >= 0.4.31 spelling
    from jax.sharding import NamedSharding, PartitionSpec as P
except ImportError:  # pragma: no cover - ancient jax
    from jax.experimental.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ShardedPropagator"]


def _is_carry(nd) -> bool:
    return nd.kind == "causal" and nd.op is not None


class _Changed:
    """One node's changed set, held in whichever form it was produced —
    a per-shard local mask chunk (sharded recomputes) or a full
    replicated DirtySet — with the other form materialized lazily and
    memoized.  This is what keeps collectives at the shard boundaries:
    a map -> map chain of sharded nodes passes local masks along with
    ZERO communication, and an all-gather happens only where a consumer
    genuinely needs the full set (a replicated node, a stencil dilate, a
    data-dependent gather edge, an output mask)."""

    __slots__ = ("sh", "nb", "_local", "_full")

    def __init__(self, sh, nb, local=None, full=None):
        assert (local is None) != (full is None)
        self.sh = sh
        self.nb = nb
        self._local = local
        self._full = full

    @property
    def is_local(self):
        return self._local is not None

    def full(self, D):
        if self._full is None:
            self.sh._count("all_gather")
            m = jax.lax.all_gather(self._local, self.sh.axis, axis=0,
                                   tiled=True)
            self._full = D.from_mask(m)
        return self._full

    def local(self):
        if self._local is None:
            self._local = self.sh._local_mask(self._full.to_mask(),
                                              self.nb // self.sh.S)
        return self._local


class ShardedPropagator:
    """Per-compiled-graph sharding layout + shard_map executables."""

    def __init__(self, cg, state):
        self.cg = cg
        self.mesh = cg.mesh
        self.axis = cg.shard_axis
        self.S = cg.num_shards
        nodes = cg.nodes
        sharded: List[bool] = []
        for nd in nodes:
            ok = nd.num_blocks % self.S == 0
            if nd.kind == "escan":
                ok = ok and cg._block_skip_ok(state["v"][nd.idx].dtype)
            elif _is_carry(nd):
                ok = ok and cg._block_skip_ok(
                    state["c"][str(nd.idx)].dtype)
            sharded.append(ok)
        self.sharded = sharded
        # Chunk views: every divisible node as a [num_blocks/S]-block
        # node, so graph_ops' per-node recomputes run unchanged on one
        # shard's chunk (sentinels, reshapes, and identity padding all
        # key off num_blocks).
        self.cnodes = [
            dataclasses.replace(nd, num_blocks=nd.num_blocks // self.S)
            if nd.num_blocks % self.S == 0 else nd for nd in nodes]
        self.vspec = tuple(P(self.axis) if sharded[nd.idx] else P()
                           for nd in nodes)
        self.cspec = {k: (P(self.axis) if sharded[int(k)] else P())
                      for k in state["c"]}
        self.state_spec = {"v": self.vspec, "c": self.cspec}
        self._mark_fns: Dict[Any, Any] = {}  # edited-input key set -> jit
        # ---- static collective tallies (observability) ----------------
        # shard_map programs are traced once per plan / edited-input key;
        # counting at the collective CALL SITES during that trace yields
        # the exact per-update-per-shard collective schedule with zero
        # runtime cost.  Tallies are overwritten at each (re)trace, so a
        # retrace can't double-count.
        self.tallies: Dict[Any, Dict[str, int]] = {}        # plan -> tally
        self.mark_tallies: Dict[Any, Dict[str, int]] = {}   # key -> tally
        self._cur_tally: Optional[Dict[str, int]] = None
        self._cur_kind = "?"

    def _count(self, op: str) -> None:
        """Tally one collective at trace time, keyed ``<edge-kind>:<op>``
        (no-op outside a tallied trace)."""
        if self._cur_tally is not None:
            k = f"{self._cur_kind}:{op}"
            self._cur_tally[k] = self._cur_tally.get(k, 0) + 1

    # ------------------------------------------------------------------
    # State placement
    # ------------------------------------------------------------------
    def place(self, state):
        """Lay the init state out over the mesh (one device_put)."""
        ns = functools.partial(NamedSharding, self.mesh)
        sh = {"v": tuple(ns(self.vspec[i]) for i in range(len(state["v"]))),
              "c": {k: ns(self.cspec[k]) for k in state["c"]}}
        return jax.device_put(state, sh)

    # ------------------------------------------------------------------
    # Executables
    # ------------------------------------------------------------------
    def mark(self, state, inputs):
        """Sharded mark pass: same outputs as ``CompiledGraph._mark_impl``
        (input masks, per-node dirty-count bounds, per-node mark masks).

        The only O(n) work in a mark is the input value diff — that runs
        on each shard's chunk in parallel, one tiny mask all-gather per
        edited input.  The mask-pushing algebra above the inputs is
        O(num_blocks) bools per node and runs replicated on the full
        masks, so it is byte-for-byte the single-device transfer code
        (letting GSPMD partition it instead costs more in collectives
        than the whole mark).  One executable is cached per edited-input
        key set."""
        key = frozenset(inputs)
        fn = self._mark_fns.get(key)
        if fn is None:
            names = sorted(key)
            smap = shardlib.shard_map(
                self._mark_body, mesh=self.mesh,
                in_specs=({"v": self.vspec, "c": self.cspec},
                          {n: self.vspec[self.cg.input_names[n]]
                           for n in names}),
                out_specs=({n: P() for n in names}, P(),
                           {str(nd.idx): P() for nd in self.cg.nodes
                            if nd.kind != "input"}))
            fn = jax.jit(smap)
            self._mark_fns[key] = fn
        return fn(state, inputs)

    def _mark_body(self, state, new_inputs):
        cg = self.cg
        D = cg._dirty_cls
        tally: Dict[str, int] = {}
        self.mark_tallies[frozenset(new_inputs)] = tally
        self._cur_tally, self._cur_kind = tally, "mark"
        dirty = [None] * len(cg.nodes)
        masks = {}
        node_masks = {}
        for nd in cg.nodes:
            if nd.kind == "input":
                if nd.name in new_inputs:
                    old = state["v"][nd.idx]
                    new = jnp.asarray(new_inputs[nd.name]).astype(
                        old.dtype)
                    dm = dirty_from_diff(old, new, nd.block)
                    if self.sharded[nd.idx]:
                        self._count("all_gather")
                        dm = jax.lax.all_gather(dm, self.axis, axis=0,
                                                tiled=True)
                    ch = D.from_mask(dm)
                    masks[nd.name] = ch.to_mask()
                else:
                    ch = D.none(nd.num_blocks)
                dirty[nd.idx] = ch
            else:
                pv = ([self._full(d, state["v"]) for d in nd.deps]
                      if nd.kind == "gather" else None)
                dirty[nd.idx] = graph_ops.edge_dirty(
                    nd, [dirty[d] for d in nd.deps], pv)
                node_masks[str(nd.idx)] = dirty[nd.idx].to_mask()
        counts = jnp.stack([dirty[nd.idx].count() for nd in cg.nodes])
        self._cur_tally = None
        return masks, counts, node_masks

    def planned_fn(self, plan):
        """One jitted shard_map executable specialized to ``plan``
        (same plan vocabulary as the single-device planned propagate).

        The wrapper narrows the argument dicts to exactly the leaves
        this plan reads — updated inputs and sparse-planned mark masks
        — so the shard_map in_specs are structurally fixed per plan.
        """
        cg = self.cg
        upd = [nd.name for nd in cg.nodes
               if nd.kind == "input" and plan[nd.idx] == "update"]
        sparse_keys = [str(i) for i, p in enumerate(plan)
                       if isinstance(p, tuple)]
        stats_spec = {
            "recomputed": P(), "affected": P(), "dirty_inputs": P(),
            "rec_per_level": P(), "aff_per_level": P(),
            "recomputed_per_shard": P(self.axis),
            "out_changed": {str(i): P() for i in cg.outputs},
            "in_dirty": {name: P() for name in cg.input_names},
        }
        smap = shardlib.shard_map(
            functools.partial(self._body, plan=plan), mesh=self.mesh,
            in_specs=({"v": self.vspec, "c": self.cspec},
                      {n: self.vspec[cg.input_names[n]] for n in upd},
                      {n: P() for n in upd},
                      {k: P() for k in sparse_keys}),
            out_specs=({"v": self.vspec, "c": self.cspec}, stats_spec))
        jfn = jax.jit(smap, donate_argnums=(0,) if cg.donate else ())

        def fn(state, new_inputs, in_masks, node_masks):
            return jfn(state, {n: new_inputs[n] for n in upd},
                       {n: in_masks[n] for n in upd},
                       {k: node_masks[k] for k in sparse_keys})

        return fn

    # ------------------------------------------------------------------
    # Shard-local helpers
    # ------------------------------------------------------------------
    def _sidx(self):
        return jax.lax.axis_index(self.axis)

    def _full(self, d: int, vals):
        """The full value of node ``d`` on every shard (all-gather a
        sharded chunk; replicated values already are full)."""
        if self.sharded[d]:
            self._count("all_gather")
            return jax.lax.all_gather(vals[d], self.axis, axis=0,
                                      tiled=True)
        return vals[d]

    def _chunk(self, d: int, vals):
        """This shard's contiguous chunk of node ``d``'s value (the
        value itself when sharded, a dynamic slice of the replicated
        full array otherwise).  Requires a divisible block count."""
        if self.sharded[d]:
            return vals[d]
        nd = self.cg.nodes[d]
        assert nd.num_blocks % self.S == 0, (nd.name, nd.num_blocks)
        ln = nd.n // self.S
        return jax.lax.dynamic_slice_in_dim(
            vals[d], self._sidx() * ln, ln, axis=0)

    def _local_mask(self, full_mask, lnb: int):
        return jax.lax.dynamic_slice_in_dim(
            full_mask, self._sidx() * lnb, lnb, axis=0)

    def _local_slice_rows(self, full, nd):
        ln = nd.n // self.S
        return jax.lax.dynamic_slice_in_dim(
            full, self._sidx() * ln, ln, axis=0)

    def _global_start(self, entry: "_Changed", nb: int):
        """First globally dirty block index of a changed set (``nb``
        when empty) — the scalar a suffix edge (causal / escan) needs.
        A local entry costs one ``pmin``; a full entry is free."""
        if not entry.is_local:
            return entry.full(self.cg._dirty_cls).start()
        lmask = entry.local()
        lnb = lmask.shape[0]
        pos = self._sidx() * lnb + jnp.arange(lnb)
        lmin = jnp.min(jnp.where(lmask, pos, nb)).astype(jnp.int32)
        self._count("pmin")
        return jax.lax.pmin(lmin, self.axis)

    def _transfer_local(self, nd, changed):
        """Shard-local dirty transfer for edges whose reader map does
        not cross chunk boundaries (exact per-block mask rep only):
        returns ``(local_mask, start_or_None, repl_count_or_None)`` or
        None when the edge needs the full-set path.  ``map``/``zip``/
        aligned ``reduce_level`` transfers are pure chunk algebra (zero
        communication); suffix edges (``causal``/``escan``) reduce to
        one scalar ``pmin`` of the parent's first dirty block, with the
        suffix count reported as a replicated scalar."""
        kind = nd.kind
        nb = nd.num_blocks
        lnb = nb // self.S
        if kind == "map":
            return changed[nd.deps[0]].local(), None, None
        if kind == "zip_map":
            return (changed[nd.deps[0]].local()
                    | changed[nd.deps[1]].local()), None, None
        if kind == "reduce_level":
            p = self.cg.nodes[nd.deps[0]]
            if p.num_blocks != 2 * nb:
                return None              # odd level: full path
            c = changed[nd.deps[0]].local()
            return c[0::2] | c[1::2], None, None
        if kind in ("causal", "escan"):
            s = self._global_start(changed[nd.deps[0]], nb)
            if kind == "escan":          # out j reads blocks < j
                s = jnp.minimum(s + 1, nb)
            pos = self._sidx() * lnb + jnp.arange(lnb)
            count = (nb - jnp.minimum(s, nb)).astype(jnp.int32)
            return (pos >= s), s, count
        return None                      # stencil / gather: full path

    def _global_row(self, x_local, gidx, ident_row):
        """Row ``gidx`` (a global block index) of a sharded per-block
        array; ``ident_row`` when ``gidx < 0``.  One tiny all-gather of
        each shard's clamped candidate row — dtype-agnostic."""
        lnb = x_local.shape[0]
        j = jnp.clip(gidx - self._sidx() * lnb, 0, lnb - 1)
        cand = jnp.take(x_local, j, axis=0)
        self._count("all_gather")
        rows = jax.lax.all_gather(cand, self.axis)          # [S, *feat]
        src = jnp.clip(gidx, 0, self.S * lnb - 1) // lnb
        row = jnp.take(rows, src, axis=0)
        return jnp.where(gidx >= 0, row, ident_row)

    def _scatter_lanes(self, nd_local, old_local, idx_local, raw):
        """Scatter k recomputed lanes into the local chunk; returns
        ``(new_local, lane_changed)`` (the lane-local cutoff)."""
        nb = nd_local.num_blocks
        old_b = old_local.reshape((nb, nd_local.block)
                                  + old_local.shape[1:])
        if nd_local.block == 1:
            vals_b = raw.reshape((idx_local.shape[0], 1) + raw.shape[1:])
        else:
            vals_b = raw
        old_lanes = old_b.at[idx_local].get(mode="fill", fill_value=0)
        lc = _lane_changed(old_lanes, vals_b)
        new = old_b.at[idx_local].set(vals_b, mode="drop")
        return new.reshape(old_local.shape), lc

    def _masked_local(self, nd_local, old_local, new_local, lmask):
        nb = nd_local.num_blocks
        new_b = new_local.reshape((nb, nd_local.block)
                                  + new_local.shape[1:])
        old_b = old_local.reshape(new_b.shape)
        return jnp.where(_bc(lmask, new_b), new_b,
                         old_b).reshape(old_local.shape)

    # ------------------------------------------------------------------
    # Stencil halos
    # ------------------------------------------------------------------
    def _stencil_windows(self, nd, vals, idx_local=None):
        """Neighbourhood windows of this shard's output blocks.  When
        the parent chunk is resident and the radius fits, halos arrive
        by ``ppermute`` — ``radius`` edge blocks per neighbour — with
        the mesh-global edges keeping the clamp/fill semantics of the
        single-device ``_windows``.  Otherwise (replicated parent, or a
        radius wider than a chunk) windows come from the full parent
        with global indices, which is bitwise the same construction.
        """
        cg = self.cg
        p = cg.nodes[nd.deps[0]]
        lnb = nd.num_blocks // self.S
        li = jnp.arange(lnb) if idx_local is None else idx_local
        if not self.sharded[nd.deps[0]] or nd.radius > lnb:
            xf = self._full(nd.deps[0], vals)
            return _windows(nd, p, xf, idx=self._sidx() * lnb + li)
        x = vals[nd.deps[0]]
        xb = x.reshape((lnb, p.block) + x.shape[1:])
        r, S = nd.radius, self.S
        self._count("ppermute")
        left = jax.lax.ppermute(xb[lnb - r:], self.axis,
                                [(j, j + 1) for j in range(S - 1)])
        self._count("ppermute")
        right = jax.lax.ppermute(xb[:r], self.axis,
                                 [(j, j - 1) for j in range(1, S)])
        if nd.fill is None:              # clamp to the global edge block
            edge_l = jnp.broadcast_to(xb[0:1], left.shape)
            edge_r = jnp.broadcast_to(xb[lnb - 1:lnb], right.shape)
        else:
            fill = jnp.asarray(nd.fill, x.dtype)
            edge_l = jnp.full(left.shape, fill)
            edge_r = jnp.full(right.shape, fill)
        sidx = self._sidx()
        left = jnp.where(sidx == 0, edge_l, left)
        right = jnp.where(sidx == S - 1, edge_r, right)
        padded = jnp.concatenate([left, xb, right], axis=0)
        parts = [padded[li + off + r] for off in range(-r, r + 1)]
        return jnp.concatenate(parts, axis=1)

    # ------------------------------------------------------------------
    # Distributed carry recombination (escan / carry-causal)
    # ------------------------------------------------------------------
    def _dist_refold(self, nd, contrib, old_local, start):
        """Sharded twin of ``graph_ops._masked_refold``: local masked
        inclusive scans, an all-gather of the S shard totals folded into
        per-shard prefixes (the cross-shard Ladner-Fischer step), and
        one seed combine per chunk.  Exact-dtype only (gated by the
        caller): the fold is re-bracketed across shard boundaries."""
        lnb = contrib.shape[0]
        pos = self._sidx() * lnb + jnp.arange(lnb)
        in_suffix = pos >= start
        ident = _identity_row(nd, contrib)
        masked = jnp.where(_bc(in_suffix, contrib), contrib, ident)
        local = jax.lax.associative_scan(nd.op, masked, axis=0)
        self._count("all_gather")
        tots = jax.lax.all_gather(local[-1], self.axis)     # [S, *feat]
        incl = jax.lax.associative_scan(nd.op, tots, axis=0)
        sidx = self._sidx()
        prefix = jnp.where(sidx > 0,
                           jnp.take(incl, jnp.maximum(sidx - 1, 0), axis=0),
                           jnp.broadcast_to(ident, contrib.shape[1:]))
        seed = self._global_row(old_local, start - 1,
                                jnp.broadcast_to(ident, contrib.shape[1:]))
        base = nd.op(seed, prefix)
        rec = jax.vmap(nd.op, in_axes=(None, 0))(base, local)
        return jnp.where(_bc(in_suffix, old_local), rec, old_local)

    def _escan_local(self, nd, vals, old_local, start, lmask):
        """Block-skip escan chunk: the previous shard's last aggregate
        row crosses the boundary by ppermute (shard 0 seeds from the op
        identity), then the distributed refold reseeds the dirty suffix
        from the cached carries."""
        agg_local = self._chunk(nd.deps[0], vals)
        ident = _identity_row(nd, agg_local)
        self._count("ppermute")
        prev = jax.lax.ppermute(agg_local[-1], self.axis,
                                [(j, j + 1) for j in range(self.S - 1)])
        first = jnp.where(self._sidx() == 0,
                          jnp.broadcast_to(ident, prev.shape), prev)
        shifted = jnp.concatenate([first[None], agg_local[:-1]], axis=0)
        new = self._dist_refold(nd, shifted, old_local, start)
        lnb = nd.num_blocks // self.S
        sel = lmask.reshape((lnb,) + (1,) * (old_local.ndim - 1))
        return jnp.where(sel, new, old_local)

    def _carry_states_local(self, nd, vals, old_states_local, start):
        p = self.cg.nodes[nd.deps[0]]
        lnb = nd.num_blocks // self.S
        plocal = self._chunk(nd.deps[0], vals)
        xb = plocal.reshape((lnb, p.block) + plocal.shape[1:])
        contrib = jax.vmap(nd.lift)(xb)
        return self._dist_refold(nd, contrib, old_states_local, start)

    # ------------------------------------------------------------------
    # Per-node local recompute
    # ------------------------------------------------------------------
    def _recompute_local(self, i, vals, carries, lmask, start, plan_i):
        """Recompute node ``i``'s local chunk from its local transfer
        mask (plus the global suffix ``start`` for escan/carry);
        returns ``(new_local, changed_local_mask, new_states_or_None)``.
        The changed mask is the lane-local Algorithm-2 cutoff applied on
        this shard's chunk only — no communication here."""
        cg = self.cg
        nd = cg.nodes[i]
        cn = self.cnodes[i]
        lnb = cn.num_blocks
        old_local = vals[i]
        sparse = isinstance(plan_i, tuple)
        if sparse:
            k = min(plan_i[1], lnb)
            li = mask_indices(lmask, k)

        def lanes_changed(li, lc):
            return jnp.zeros((lnb,), bool).at[li].set(lc, mode="drop")

        def diff_changed(new):
            return dirty_from_diff(old_local, new, nd.block) & lmask

        if nd.kind == "escan":
            new = self._escan_local(nd, vals, old_local, start, lmask)
            return new, diff_changed(new), None

        if _is_carry(nd):
            states = self._carry_states_local(nd, vals, carries[str(i)],
                                              start)
            plocal = self._chunk(nd.deps[0], vals)
            if sparse:
                new, _, lc = graph_ops.causal_finalize_sparse(
                    cn, self.cnodes, plocal, states, old_local, lmask,
                    k, idx=li)
                return new, lanes_changed(li, lc), states
            new = graph_ops.causal_finalize_dense(
                cn, self.cnodes, plocal, states, old_local, lmask)
            return new, diff_changed(new), states

        if nd.kind in ("map", "zip_map") or (
                nd.kind == "reduce_level"
                and cg.nodes[nd.deps[0]].num_blocks == 2 * nd.num_blocks):
            parents = [self._chunk(d, vals) for d in nd.deps]
            if sparse:
                new, _, lc = graph_ops.sparse_update(
                    cn, self.cnodes, parents, old_local, lmask, k, idx=li)
                return new, lanes_changed(li, lc), None
            new = graph_ops.dense_update(
                cn, self.cnodes, parents, old_local, lmask)
            return new, diff_changed(new), None

        if nd.kind == "reduce_level":
            # Non-aligned level (identity-padded odd parent): combine
            # from the all-gathered parent — the reduce tree's
            # all-gather-then-local-combine fallback.
            pf = self._full(nd.deps[0], vals)
            full = graph_ops.forward(nd, cg.nodes, [pf])
            new_rows = self._local_slice_rows(full, nd)
            new = self._masked_local(cn, old_local, new_rows, lmask)
            return new, diff_changed(new), None

        if nd.kind == "stencil":
            if sparse:
                win = self._stencil_windows(nd, vals, idx_local=li)
                raw = jax.vmap(nd.fn)(win)
                new, lc = self._scatter_lanes(cn, old_local, li, raw)
                return new, lanes_changed(li, lc), None
            win = self._stencil_windows(nd, vals)
            raw = jax.vmap(nd.fn)(win)
            new = self._masked_local(
                cn, old_local, graph_ops._pack(cn, raw), lmask)
            return new, diff_changed(new), None

        if nd.kind in ("causal", "gather"):
            # Full-prefix / data-dependent readers: the parent must be
            # visible in full; output lanes stay shard-local.
            xf = self._full(nd.deps[0], vals)
            g0 = self._sidx() * lnb
            if sparse:
                raw = self._lane_fn(nd, xf, g0, li, k)
                new, lc = self._scatter_lanes(cn, old_local, li, raw)
                return new, lanes_changed(li, lc), None
            raw = self._lane_fn(nd, xf, g0, jnp.arange(lnb), lnb)
            new = self._masked_local(
                cn, old_local, graph_ops._pack(cn, raw), lmask)
            return new, diff_changed(new), None

        raise ValueError(nd.kind)        # pragma: no cover

    def _lane_fn(self, nd, x_full, g0, li, k: int):
        """Per-lane recompute of causal / gather lanes at local indices
        ``li`` (global ``g0 + li``); packed gather reads only its own +
        neighbour blocks."""
        p = self.cg.nodes[nd.deps[0]]
        if nd.kind == "gather" and nd.packed_fn is not None:
            xb = x_full.reshape((p.num_blocks, p.block) + x_full.shape[1:])
            own = xb.at[g0 + li].get(mode="fill", fill_value=0)
            nidx = jnp.clip(jnp.asarray(nd.idx_fn(own), jnp.int32),
                            0, nd.num_blocks - 1)
            return jax.vmap(nd.packed_fn)(own, xb[nidx])
        gi = jnp.minimum(g0 + li, nd.num_blocks)  # keep sentinel OOB-safe
        return jax.vmap(nd.fn, in_axes=(None, 0))(x_full, gi)

    # ------------------------------------------------------------------
    # Replicated recompute (every shard runs the single-device path)
    # ------------------------------------------------------------------
    def _recompute_repl(self, i, vals, carries, dirty, plan_i,
                        node_masks):
        cg = self.cg
        nd = cg.nodes[i]
        parents = [self._full(d, vals) for d in nd.deps]
        idx = None
        regime = "dense"
        if isinstance(plan_i, tuple):
            regime = "sparse"
            idx = mask_indices(node_masks[str(i)], plan_i[1])
        return cg._recompute(nd, parents, vals[i], dirty,
                             carries.get(str(i)), regime=regime, idx=idx)

    # ------------------------------------------------------------------
    # The shard_map body
    # ------------------------------------------------------------------
    def _body(self, state, new_inputs, in_masks, node_masks, *, plan):
        """The shard_map body of one planned update.

        Dirty bookkeeping is two-tier: ``_Changed`` entries hold each
        node's changed set in per-shard local form where it was produced
        locally, and counts accumulate into replicated scalars
        (``*_repl``, from full sets) plus per-shard scalars (``*_loc``,
        from local masks) that ONE final ``psum`` folds together — so a
        chain of aligned sharded nodes propagates with no collectives
        at all, and the totals are still exactly the single-device
        counts (local masks partition the global mask)."""
        cg = self.cg
        D = cg._dirty_cls
        tally: Dict[str, int] = {}
        self.tallies[plan] = tally
        self._cur_tally, self._cur_kind = tally, "input"
        # Local-mask shortcuts are exact only for the exact per-block
        # mask rep; the interval rep's transfers are hulls, so parity
        # requires running its (full-set) algebra verbatim.
        local_ok = cg.dirty_rep == "mask"
        nodes = cg.nodes
        L = cg.num_levels
        vals = list(state["v"])
        carries = dict(state["c"])
        changed: List[Optional[_Changed]] = [None] * len(nodes)
        rec_repl = jnp.int32(0)
        aff_repl = jnp.int32(0)
        rec_loc = jnp.int32(0)           # per-shard, psummed at the end
        aff_loc = jnp.int32(0)
        dirty_inputs = jnp.int32(0)
        local_rec = jnp.int32(0)         # per-shard work stat
        any_local = False
        # Per-level twins of the four accumulators above; merged by the
        # SAME single psum (stacked alongside the totals), so the
        # observability columns cost zero extra collectives.
        rl_repl = [jnp.int32(0) for _ in range(L)]
        al_repl = [jnp.int32(0) for _ in range(L)]
        rl_loc = [jnp.int32(0) for _ in range(L)]
        al_loc = [jnp.int32(0) for _ in range(L)]

        def full_of(e):
            return e.full(D)

        for li, lvl in enumerate(cg.schedule):
            self._cur_kind = "input"
            for idx in lvl:
                nd = nodes[idx]
                if nd.kind != "input":
                    continue
                if plan[idx] != "update":
                    changed[idx] = _Changed(self, nd.num_blocks,
                                            full=D.none(nd.num_blocks))
                    continue
                vals[idx] = jnp.asarray(new_inputs[nd.name]).astype(
                    vals[idx].dtype)
                ch = D.from_mask(in_masks[nd.name])
                changed[idx] = _Changed(self, nd.num_blocks, full=ch)
                dirty_inputs += ch.count()

            for i in lvl:
                nd = nodes[i]
                if nd.kind == "input":
                    continue
                self._cur_kind = nd.kind
                if plan[i] == "skip":
                    changed[i] = _Changed(self, nd.num_blocks,
                                          full=D.none(nd.num_blocks))
                    continue
                lnb = nd.num_blocks // self.S
                loc = (self._transfer_local(nd, changed)
                       if self.sharded[i] and local_ok else None)
                if loc is not None:
                    lmask, start, repl_count = loc
                    lrec = jnp.sum(lmask.astype(jnp.int32))
                    if repl_count is not None:   # suffix edge: exact
                        rec_repl += repl_count
                        rl_repl[li] += repl_count
                    else:
                        rec_loc += lrec
                        rl_loc[li] += lrec
                    nv, chl, st = self._recompute_local(
                        i, vals, carries, lmask, start, plan[i])
                    changed[i] = _Changed(self, nd.num_blocks, local=chl)
                    laff = jnp.sum(chl.astype(jnp.int32))
                    aff_loc += laff
                    al_loc[li] += laff
                    any_local = True
                    local_rec += lrec
                else:
                    pv = ([self._full(d, vals) for d in nd.deps]
                          if nd.kind == "gather" else None)
                    dirty = graph_ops.edge_dirty(
                        nd, [full_of(changed[d]) for d in nd.deps], pv)
                    rec_repl += dirty.count()
                    rl_repl[li] += dirty.count()
                    if self.sharded[i]:
                        lmask = self._local_mask(dirty.to_mask(), lnb)
                        nv, chl, st = self._recompute_local(
                            i, vals, carries, lmask, dirty.start(),
                            plan[i])
                        if local_ok:
                            changed[i] = _Changed(self, nd.num_blocks,
                                                  local=chl)
                            laff = jnp.sum(chl.astype(jnp.int32))
                            aff_loc += laff
                            al_loc[li] += laff
                            any_local = True
                        else:
                            # Interval parity: hull the changed set on
                            # its full form, count the hull.
                            ch = _Changed(self, nd.num_blocks,
                                          local=chl).full(D)
                            changed[i] = _Changed(self, nd.num_blocks,
                                                  full=ch)
                            aff_repl += ch.count()
                            al_repl[li] += ch.count()
                        local_rec += jnp.sum(lmask.astype(jnp.int32))
                    else:
                        nv, ch, st = self._recompute_repl(
                            i, vals, carries, dirty, plan[i], node_masks)
                        changed[i] = _Changed(self, nd.num_blocks,
                                              full=ch)
                        aff_repl += ch.count()
                        al_repl[li] += ch.count()
                        local_rec += dirty.count()
                vals[i] = nv
                if st is not None:
                    carries[str(i)] = st

        self._cur_kind = "stats"
        if any_local:
            # One psum folds the scalar totals (column 0 — bitwise the
            # pre-observability [2]-vector psum) and the per-level
            # columns together.
            loc = jnp.stack([jnp.stack([rec_loc] + rl_loc),
                             jnp.stack([aff_loc] + al_loc)])
            self._count("psum")
            tot = jax.lax.psum(loc, self.axis)
            recomputed = rec_repl + tot[0, 0]
            affected = aff_repl + tot[1, 0]
            rec_per_level = jnp.stack(rl_repl) + tot[0, 1:]
            aff_per_level = jnp.stack(al_repl) + tot[1, 1:]
        else:
            recomputed, affected = rec_repl, aff_repl
            rec_per_level = jnp.stack(rl_repl)
            aff_per_level = jnp.stack(al_repl)

        stats = {
            "recomputed": recomputed, "affected": affected,
            "dirty_inputs": dirty_inputs,
            "rec_per_level": rec_per_level,
            "aff_per_level": aff_per_level,
            "recomputed_per_shard": local_rec[None],
            "out_changed": {str(i): full_of(changed[i]).to_mask()
                            for i in cg.outputs},
            "in_dirty": {name: full_of(changed[idx]).count()
                         for name, idx in cg.input_names.items()},
        }
        self._cur_tally = None
        return {"v": tuple(vals), "c": carries}, stats
