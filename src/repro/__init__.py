"""repro: parallel self-adjusting computation, scaled to a multi-pod JAX
training/serving framework.

Layers:
  * ``repro.core``    — the paper's algorithm (RSP trees, change propagation).
  * ``repro.jaxsac``  — TPU-native compiled adaptation (block dataflow).
  * ``repro.models``  — the 10 assigned architectures.
  * ``repro.kernels`` — Pallas TPU kernels (+ jnp oracles).
  * ``repro.launch``  — meshes, sharding, multi-pod dry-run, train/serve.
"""
__version__ = "0.1.0"
